"""llama3-405b — dense GQA, 128k vocab [arXiv:2407.21783].

fsdp=True: 405B params do not fit a 16-way model shard on 16 GB v5e
(bf16 alone is 50 GB/chip); weights/optimizer shard over the data axis
too (ZeRO-3 style), at the cost of per-layer all-gathers — quantified
in EXPERIMENTS.md §Roofline.
"""
from repro.configs.common import smoke_reduce
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
        d_ff=53248, vocab=128256, head_dim=128, rope_theta=500000.0,
        fsdp=True, microbatches=16, seq_shard=True,
        source="arXiv:2407.21783",
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config())
