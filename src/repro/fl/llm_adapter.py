"""MA-Echo over the LLM zoo — cross-silo fine-tuning aggregation.

Maps every parameter leaf of every architecture family onto one of the
projector rules from ``repro.core.maecho`` (DESIGN.md §4):

  full    — (d_in, d_in) projector from captured layer-input features
  diag    — embedding tables: the input space is the one-hot vocab, so
            P is the client's token-support indicator (d=vocab diag)
  scalar  — biases, norms, SSM diagonal params (A_log, D, dt_bias),
            depthwise conv taps: the input is always live, the paper's
            null space is degenerate (paper §6), so the bias rule holds

Feature capture (``probe_features``) re-runs the forward as an
*unstacked* python-loop over layers (client-side, smoke/fine-tune
scale), collecting the exact input stream of each matmul.  For MoE, the
features for expert e are the tokens *routed to e* — per-expert
projectors over disjoint input subspaces, the paper's non-IID sweet
spot realised inside a single model.

Weight convention here is "io" (x @ W) throughout.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import projections as proj
from repro.core.maecho import MAEchoConfig, maecho_aggregate
from repro.models import dense, moe as moe_mod
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.utils import trees


# --------------------------------------------------------------------------
# stack levels: how many leading layer axes each leaf carries
# --------------------------------------------------------------------------
def stack_levels_fn(cfg: ModelConfig) -> Callable[[str], int]:
    def fn(path: str) -> int:
        if cfg.family == "hybrid":
            return 2 if path.startswith("mamba.") else 0
        if _expert_leaf(path):
            return 2                    # (L, E) — per-layer, per-expert
        if path.startswith(("layers.", "enc_layers.", "dec_layers.")):
            return 1
        return 0
    return fn


def _expert_leaf(path: str) -> bool:
    return any(k in path for k in ("we_gate", "we_up", "we_down"))


# --------------------------------------------------------------------------
# projector construction
# --------------------------------------------------------------------------
def _full_P(feats, alpha):
    f = feats.reshape(-1, feats.shape[-1]).astype(jnp.float32)
    f = f / jnp.maximum(jnp.linalg.norm(f, axis=-1, keepdims=True), 1e-6)
    return proj.projection_from_features(f, alpha)


def default_llm_projections(cfg: ModelConfig, params, alpha: float = 1.0,
                            token_support=None):
    """Scalar rule everywhere, diag on the embedding if token_support
    (bool (vocab,)) is given.  The fallback when no probe exists."""
    def mk(path, leaf):
        lead = _lead_shape(cfg, path, leaf)
        if path == "embed" and token_support is not None:
            return token_support.astype(leaf.dtype)
        return jnp.ones(lead, jnp.float32)
    return trees.map_with_path(mk, params)


def _lead_shape(cfg: ModelConfig, path: str, leaf):
    lv = stack_levels_fn(cfg)(path)
    return leaf.shape[:lv]


def build_projections(cfg: ModelConfig, params, batches,
                      alpha: float = 1.0):
    """Capture features over ``batches`` and build the projector pytree.

    Leaves with a captured feature stream get full per-layer P; the
    embedding gets the diag token-support rule; everything else the
    scalar rule.
    """
    feats, support = probe_features(cfg, params, batches)
    expert = feats.pop("__expert__", None)

    def build(f):
        if isinstance(f, list):
            if isinstance(f[0], list):         # hybrid (G, k) nesting
                return jnp.stack([jnp.stack([_full_P(x, alpha)
                                             for x in row]) for row in f])
            return jnp.stack([_full_P(x, alpha) for x in f])
        return _full_P(f, alpha)

    def mk(path, leaf):
        if path in feats:
            return build(feats[path])
        if expert is not None and path in ("layers.we_gate",
                                           "layers.we_up"):
            # per-expert projectors from the routed token streams
            return jnp.stack([
                jax.vmap(lambda fe: _full_P(fe, alpha))(expert[l])
                for l in range(leaf.shape[0])])
        if path == "embed" and support is not None:
            return support.astype(jnp.float32)
        return jnp.ones(_lead_shape(cfg, path, leaf), jnp.float32)

    return trees.map_with_path(mk, params)


# --------------------------------------------------------------------------
# feature probes (unstacked forward, python loop over layers)
# --------------------------------------------------------------------------
def probe_features(cfg: ModelConfig, params, batches):
    if cfg.family in ("dense", "vlm"):
        return _probe_dense(cfg, params, batches)
    if cfg.family == "moe":
        return _probe_moe(cfg, params, batches)
    if cfg.family == "ssm":
        return _probe_mamba(cfg, params, batches)
    if cfg.family == "hybrid":
        return _probe_hybrid(cfg, params, batches)
    if cfg.family == "encdec":
        return _probe_encdec(cfg, params, batches)
    raise ValueError(cfg.family)


def _collect(store, key, val, max_rows=1024):
    v = val.reshape(-1, val.shape[-1])
    if v.shape[0] > max_rows:
        v = v[:: max(1, v.shape[0] // max_rows)][:max_rows]
    store.setdefault(key, []).append(v)


def _cat(store):
    return {k: ([jnp.concatenate(x, 0) for x in zip(*v)]
                if isinstance(v[0], (list, tuple))
                else jnp.concatenate(v, 0))
            for k, v in store.items()}


def _token_support(cfg, batches):
    sup = np.zeros(cfg.vocab, np.float32)
    for b in batches:
        if "tokens" in b:
            sup[np.unique(np.asarray(b["tokens"]))] = 1.0
    return jnp.asarray(sup)


def _probe_dense(cfg: ModelConfig, params, batches):
    nL = cfg.n_layers
    per_layer: dict[str, list] = {}
    final_feats = []
    for batch in batches:
        x, positions = dense.embed_inputs(cfg, params, batch)
        rows = [dict() for _ in range(nL)]
        for l in range(nL):
            lp = trees.tree_map(lambda a: a[l], params["layers"])
            h1 = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            _collect(rows[l], "qkv", h1)
            a = dense.attn_block(lp, h1, positions, cfg)
            # input of wo is attention output pre-projection; reuse a's
            # pre-wo stream via a dedicated recompute:
            x = x + a
            h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            _collect(rows[l], "mlp_in", h2)
            x = x + dense.mlp_block(lp, h2, cfg)
        xf = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        final_feats.append(xf.reshape(-1, cfg.d_model)[:1024])
        for l in range(nL):
            for k, v in rows[l].items():
                per_layer.setdefault((k, l), []).extend(v)

    feats = {}
    for name, param_keys in (("qkv", ("layers.wq", "layers.wk",
                                      "layers.wv")),
                             ("mlp_in", ("layers.w_gate", "layers.w_up"))):
        stacked = [jnp.concatenate(per_layer[(name, l)], 0)
                   for l in range(nL)]
        for pk in param_keys:
            feats[pk] = stacked
    out = {k: v for k, v in feats.items()}
    if not cfg.tie_embeddings:
        out["lm_head"] = jnp.concatenate(final_feats, 0)
    support = _token_support(cfg, batches)
    return out, support


def _probe_moe(cfg: ModelConfig, params, batches):
    nL = cfg.n_layers
    m = cfg.moe
    per_layer: dict = {}
    expert_feats: dict = {}
    final_feats = []
    for batch in batches:
        x, positions = dense.embed_inputs(cfg, params, batch)
        for l in range(nL):
            lp = trees.tree_map(lambda a: a[l], params["layers"])
            h1 = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            per_layer.setdefault(("qkv", l), []).append(
                h1.reshape(-1, cfg.d_model)[:512])
            x = x + dense.attn_block(lp, h1, positions, cfg)
            h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            per_layer.setdefault(("router", l), []).append(
                h2.reshape(-1, cfg.d_model)[:512])
            # routed per-expert features
            B, S, d = h2.shape
            T = B * S
            g = min(m.group_size, T)
            pad = (-T) % g
            xg = jnp.pad(h2.reshape(T, d), ((0, pad), (0, 0)))
            xg = xg.reshape(-1, g, d)
            dispatch, _, _ = moe_mod._route(lp, xg, cfg)
            xe = jnp.einsum("ngec,ngd->necd", dispatch, xg)
            xe = xe.transpose(1, 0, 2, 3).reshape(m.n_experts, -1, d)
            expert_feats.setdefault(l, []).append(xe[:, :256])
            y, _ = moe_mod.moe_block(lp, h2, cfg)
            x = x + y
        xf = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        final_feats.append(xf.reshape(-1, cfg.d_model)[:1024])

    feats: dict = {}
    qkv = [jnp.concatenate(per_layer[("qkv", l)], 0) for l in range(nL)]
    for pk in ("layers.wq", "layers.wk", "layers.wv"):
        feats[pk] = qkv
    router = [jnp.concatenate(per_layer[("router", l)], 0)
              for l in range(nL)]
    feats["layers.router"] = router
    if m.n_shared_experts:
        feats["layers.ws_gate"] = router
        feats["layers.ws_up"] = router
    if not cfg.tie_embeddings:
        feats["lm_head"] = jnp.concatenate(final_feats, 0)
    # expert leaves: handled separately in build_projections_moe below
    support = _token_support(cfg, batches)
    feats["__expert__"] = {
        l: jnp.concatenate(v, 1) for l, v in expert_feats.items()}
    return feats, support


def _probe_mamba(cfg: ModelConfig, params, batches):
    from repro.models import mamba
    nL = cfg.n_layers
    per_layer: dict = {}
    final_feats = []
    for batch in batches:
        x = params["embed"].astype(cfg.cdtype)[batch["tokens"]]
        for l in range(nL):
            lp = trees.tree_map(lambda a: a[l], params["layers"])
            h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
            per_layer.setdefault(("in_proj", l), []).append(
                h.reshape(-1, cfg.d_model)[:512])
            x = x + mamba.mamba1_block(lp, h, cfg)
        xf = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        final_feats.append(xf.reshape(-1, cfg.d_model)[:1024])
    feats = {"layers.in_proj":
             [jnp.concatenate(per_layer[("in_proj", l)], 0)
              for l in range(nL)]}
    if not cfg.tie_embeddings:
        feats["lm_head"] = jnp.concatenate(final_feats, 0)
    return feats, _token_support(cfg, batches)


def _probe_hybrid(cfg: ModelConfig, params, batches):
    from repro.models import hybrid as hy
    from repro.models import mamba
    G = cfg.n_layers // cfg.hybrid.attn_every
    k = cfg.hybrid.attn_every
    shared_in: list = []
    mamba_in: dict = {}
    final_feats = []
    sp = params["shared_attn"]
    for batch in batches:
        x = params["embed"].astype(cfg.cdtype)[batch["tokens"]]
        B, S, _ = x.shape
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        for g in range(G):
            h1 = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
            shared_in.append(h1.reshape(-1, cfg.d_model)[:512])
            x = x + dense.attn_block(sp, h1, positions, cfg)
            x = x + dense.mlp_block(
                sp, L.rms_norm(x, sp["ln2"], cfg.norm_eps), cfg)
            for j in range(k):
                lp = trees.tree_map(lambda a: a[g, j], params["mamba"])
                h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
                mamba_in.setdefault((g, j), []).append(
                    h.reshape(-1, cfg.d_model)[:256])
                x = x + mamba.mamba2_block(lp, h, cfg)
        xf = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        final_feats.append(xf.reshape(-1, cfg.d_model)[:1024])
    # stacked (G, k) leaf -> list-of-lists flattened in scan order
    feats = {
        "shared_attn.wq": jnp.concatenate(shared_in, 0),
        "shared_attn.wk": jnp.concatenate(shared_in, 0),
        "shared_attn.wv": jnp.concatenate(shared_in, 0),
        "mamba.in_proj": [[jnp.concatenate(mamba_in[(g, j)], 0)
                           for j in range(k)] for g in range(G)],
    }
    if not cfg.tie_embeddings:
        feats["lm_head"] = jnp.concatenate(final_feats, 0)
    return feats, _token_support(cfg, batches)


def _probe_encdec(cfg: ModelConfig, params, batches):
    from repro.models import encdec as ed
    nL = cfg.n_layers
    nE = cfg.encdec.n_enc_layers
    store: dict = {}
    for batch in batches:
        enc_out = ed.encode(cfg, params, batch["audio_embeds"])
        x = params["embed"].astype(cfg.cdtype)[batch["tokens"]]
        Sd = batch["tokens"].shape[1]
        x = x + params["dec_pos"].astype(cfg.cdtype)[:Sd]
        for l in range(nL):
            lp = trees.tree_map(lambda a: a[l], params["dec_layers"])
            h = L.layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
            _collect(store, ("dec_self", l), h)
            x = x + ed._mha(lp, h, h, cfg, causal=True)
            h = L.layer_norm(x, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
            _collect(store, ("dec_crossq", l), h)
            _collect(store, ("dec_crosskv", l), enc_out)
            x = x + ed._mha(lp, h, enc_out, cfg, causal=False, pre="x")
            h = L.layer_norm(x, lp["ln3_g"], lp["ln3_b"], cfg.norm_eps)
            _collect(store, ("dec_mlp", l), h)
            x = x + L.gelu_mlp(h, lp["w_in"].astype(cfg.cdtype),
                               lp["b_in"].astype(cfg.cdtype),
                               lp["w_out"].astype(cfg.cdtype),
                               lp["b_out"].astype(cfg.cdtype))

    def stack(name, n):
        return [jnp.concatenate(store[(name, l)], 0) for l in range(n)]

    feats = {
        "dec_layers.wq": stack("dec_self", nL),
        "dec_layers.wk": stack("dec_self", nL),
        "dec_layers.wv": stack("dec_self", nL),
        "dec_layers.wxq": stack("dec_crossq", nL),
        "dec_layers.wxk": stack("dec_crosskv", nL),
        "dec_layers.wxv": stack("dec_crosskv", nL),
        "dec_layers.w_in": stack("dec_mlp", nL),
    }
    return feats, _token_support(cfg, batches)


# --------------------------------------------------------------------------
# aggregation entry point
# --------------------------------------------------------------------------
def aggregate_llm(cfg: ModelConfig, client_params: list,
                  client_projs: list = None,
                  macfg: MAEchoConfig = MAEchoConfig(tau=20, eta=0.5),
                  backend: str = "auto", mesh=None):
    """One-shot MA-Echo over fine-tuned LLM checkpoints.

    ``backend="auto"`` (default) promotes every leaf big enough to
    tile — including the scan-over-layers transformer stacks, whose
    layer axis folds into the stacked kernel grid — to the fused
    Pallas pipeline; smoke-scale models (dims below one 128-tile)
    degrade to the oracle with identical results.  Pass
    ``backend="sharded"`` plus a ``mesh`` to additionally split leaf
    out-rows across devices (one psum per leaf per outer iteration),
    or ``backend="sharded2d"`` plus a mesh carrying both
    ``macfg.mesh_axis`` and ``macfg.mesh_in_axis`` to shard the
    residual 2-D (out × in) — the route for attention/MLP leaves
    whose out-dim alone cannot span the fleet (still one psum, taken
    over both axis groups).  Routing is compiled once per model shape
    into an ``AggPlan`` (``core.plan``); inspect it with
    ``core.maecho.dispatch_summary`` or ``dryrun_agg --backend ...``.
    """
    if client_projs is None:
        client_projs = [default_llm_projections(cfg, p)
                        for p in client_params]
    return maecho_aggregate(
        client_params, client_projs, macfg, convention="io",
        stack_levels=stack_levels_fn(cfg), backend=backend, mesh=mesh)
