"""Regenerate the dry-run/roofline tables inside EXPERIMENTS.md from
``experiments/dryrun/*.json``.

  PYTHONPATH=src python -m benchmarks.update_experiments
"""
from __future__ import annotations

import re

from benchmarks.roofline_report import (load_records, markdown_table,
                                        memory_table)


def replace_block(text: str, marker: str, content: str) -> str:
    pattern = rf"<!-- {marker} -->.*?(?=\n## |\Z)"
    block = f"<!-- {marker} -->\n\n{content}\n"
    if re.search(pattern, text, flags=re.S):
        return re.sub(pattern, block, text, flags=re.S)
    return text


def main() -> None:
    recs = load_records()
    with open("EXPERIMENTS.md") as f:
        text = f.read()

    dry = (
        "### Compile/memory census — 16×16 (256 chips)\n\n"
        + memory_table(recs, "16x16")
        + "\n\n### Compile/memory census — 2×16×16 (512 chips, "
        "multi-pod)\n\n" + memory_table(recs, "2x16x16"))
    roof = (
        "### Single-pod 16×16\n\n" + markdown_table(recs, "16x16")
        + "\n\n### Multi-pod 2×16×16\n\n"
        + markdown_table(recs, "2x16x16"))

    text = replace_block(text, "DRYRUN_TABLE", dry)
    text = replace_block(text, "ROOFLINE_TABLE", roof)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    print(f"EXPERIMENTS.md updated: {n_ok}/{len(recs)} records ok")


if __name__ == "__main__":
    main()
