"""falcon-mamba-7b — attention-free Mamba1 [arXiv:2410.05355].
64 layers, d_model=4096 (d_inner=8192), ssm_state=16, vocab 65024."""
from repro.configs.common import smoke_reduce
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm",
        n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=65024,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=1),
        microbatches=16,
        source="arXiv:2410.05355",
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config(), n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0)
