"""Architecture config registry.

``get_config(arch_id)`` returns the exact published config;
``get_smoke_config(arch_id)`` returns the reduced same-family variant
used by the CPU smoke tests (2 layers, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "llama3_8b", "qwen2_1_5b", "whisper_tiny", "falcon_mamba_7b",
    "phi3_vision_4_2b", "qwen2_moe_a2_7b", "llama3_405b", "zamba2_2_7b",
    "qwen2_0_5b", "grok1_314b",
    # paper's own experiment configs
    "paper_mlp", "paper_cnn", "paper_cvae",
]

# public ids use dashes (CLI --arch); module names use underscores
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "llama3-8b": "llama3_8b", "qwen2-1.5b": "qwen2_1_5b",
    "whisper-tiny": "whisper_tiny", "falcon-mamba-7b": "falcon_mamba_7b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b", "llama3-405b": "llama3_405b",
    "zamba2-2.7b": "zamba2_2_7b", "qwen2-0.5b": "qwen2_0_5b",
    "grok-1-314b": "grok1_314b",
})


def _module(arch: str):
    name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def assigned_archs() -> list[str]:
    """The ten architectures assigned from the public pool."""
    return [a for a in ARCH_IDS if not a.startswith("paper_")]
