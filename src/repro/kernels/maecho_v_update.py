"""Pallas TPU kernel: fused MA-Echo anchor update (Eq. 11).

Computes, for every client i,

    Vᵢ' = Vᵢ + Norm( Δᵢ − μ/(1+μ) · Δᵢ Pᵢ ),   Δᵢ = W' − Vᵢ

i.e. the residual re-projected through (I − μ/(1+μ)Pᵢ), with the
optional row-normalisation.  The reference path materializes the
(N, out, in) Δᵢ Pᵢ product in HBM; here each output tile keeps the
whole chain in VMEM: Δ tiles are formed in-register from W'/Vᵢ blocks,
the Δᵢ Pᵢ contraction accumulates in a (bo, bi) fp32 scratch across
the k-grid axis, and the finalize step fuses the subtraction, optional
row-norm and the += into a single store of Vᵢ'.

Grid: (N, n_out, n_in, n_k); scratch persists across the innermost
axis only (one tile's reduction).  With ``norm=True`` the row norm
needs the full row resident, so callers must set bi = in_d (the auto
wrapper in ``ops`` does; rows up to ~16k fp32 fit VMEM comfortably).

Fast paths mirror ``maecho_gram``:
  - ``maecho_v_update_factored``: Δᵢ Pᵢ = Bᵢ @ Uᵢᵀ with the compressed
    Bᵢ = ((W' − Vᵢ)Uᵢ)·diag(sᵢ) formed without the full residual —
    reduction runs over the rank k instead of in;
  - ``maecho_v_update_diag``: elementwise Δᵢ·(1 − μ/(1+μ)·pᵢ), one
    pass, no reduction axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _apply_norm(u, eps: float):
    """Row-normalise u (bo, full-row) exactly like the jnp oracle."""
    nrm = jnp.sqrt(jnp.sum(u * u, axis=-1, keepdims=True))
    return u / jnp.maximum(nrm, eps)


def _v_tail(contrib, wj_ref, vj_ref, out_ref, acc_ref,
            *, frac: float, norm: bool, eps: float, n_k: int,
            off: int = 0):
    """Accumulate one k-block of Δᵢ Pᵢ, then fuse Eq. 11 at the end.

    ``off`` is the grid offset of the (client, out, in, k) axes — 1
    when the stacked-layer axis rides in front."""
    k = pl.program_id(off + 3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += contrib

    @pl.when(k == n_k - 1)
    def _finalize():
        dj = (wj_ref[...] - vj_ref[...]).astype(jnp.float32)  # (bo, bi)
        u = dj - frac * acc_ref[...]
        if norm:
            u = _apply_norm(u, eps)
        out_ref[...] = (vj_ref[...].astype(jnp.float32) + u
                        ).astype(out_ref.dtype)


def _v_kernel_dense(w_ref, v_ref, p_ref, wj_ref, vj_ref, out_ref,
                    acc_ref, *, frac, norm, eps, n_k, off=0):
    contrib = jax.lax.dot((w_ref[...] - v_ref[...]).astype(jnp.float32),
                          p_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)
    _v_tail(contrib, wj_ref, vj_ref, out_ref, acc_ref,
            frac=frac, norm=norm, eps=eps, n_k=n_k, off=off)


def _v_kernel_left(b_ref, ut_ref, wj_ref, vj_ref, out_ref,
                   acc_ref, *, frac, norm, eps, n_k, off=0):
    contrib = jax.lax.dot(b_ref[...].astype(jnp.float32),
                          ut_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)
    _v_tail(contrib, wj_ref, vj_ref, out_ref, acc_ref,
            frac=frac, norm=norm, eps=eps, n_k=n_k, off=off)


@functools.partial(jax.jit, static_argnames=("frac", "norm", "eps",
                                             "bo", "bi", "bk",
                                             "interpret"))
def maecho_v_update(W, V, P, *, frac: float, norm: bool = False,
                    eps: float = 1e-12, bo: int = 128, bi: int = 128,
                    bk: int = 128, interpret: bool = True):
    """W: (out, in) updated global; V: (N, out, in); P: (N, in, in).

    Returns V' per Eq. 11.  ``frac`` is μ/(1+μ).  With ``norm=True``
    the caller must pass bi = in_d (full rows resident for the norm).
    """
    out_d, in_d = W.shape
    N = V.shape[0]
    bo, bi, bk = min(bo, out_d), min(bi, in_d), min(bk, in_d)
    if norm:
        assert bi == in_d, "row-norm needs full rows: set bi = in_d"
    assert out_d % bo == 0 and in_d % bi == 0 and in_d % bk == 0, (
        "pad layer dims to block multiples (ops.maecho_v_update_auto)")
    n_out, n_in, n_k = out_d // bo, in_d // bi, in_d // bk
    kernel = functools.partial(_v_kernel_dense, frac=frac, norm=norm,
                               eps=eps, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(N, n_out, n_in, n_k),
        in_specs=[
            pl.BlockSpec((bo, bk), lambda i, o, j, k: (o, k)),       # W (red.)
            pl.BlockSpec((None, bo, bk), lambda i, o, j, k: (i, o, k)),  # V
            pl.BlockSpec((None, bk, bi), lambda i, o, j, k: (i, k, j)),  # P
            pl.BlockSpec((bo, bi), lambda i, o, j, k: (o, j)),       # W (out)
            pl.BlockSpec((None, bo, bi), lambda i, o, j, k: (i, o, j)),  # V
        ],
        out_specs=pl.BlockSpec((None, bo, bi), lambda i, o, j, k: (i, o, j)),
        out_shape=jax.ShapeDtypeStruct(V.shape, V.dtype),
        scratch_shapes=[pltpu.VMEM((bo, bi), jnp.float32)],
        interpret=interpret,
    )(W, V, P, W, V)


@functools.partial(jax.jit, static_argnames=("frac", "norm", "eps",
                                             "bo", "bi", "bk",
                                             "interpret"))
def maecho_v_update_factored(W, V, U, s, *, frac: float,
                             norm: bool = False, eps: float = 1e-12,
                             bo: int = 128, bi: int = 128, bk: int = 128,
                             interpret: bool = True):
    """Factored Pᵢ = Uᵢ·diag(sᵢ)·Uᵢᵀ.  U: (N, in, k); s: (N, k)."""
    from repro.kernels.maecho_gram import compressed_residual

    out_d, in_d = W.shape
    N, _, kd = U.shape
    bo, bi, bk = min(bo, out_d), min(bi, in_d), min(bk, kd)
    if norm:
        assert bi == in_d, "row-norm needs full rows: set bi = in_d"
    assert out_d % bo == 0 and in_d % bi == 0 and kd % bk == 0, (
        "pad layer dims / rank to block multiples")
    B = compressed_residual(W, V, U, s)                  # (N, out, k)
    UT = jnp.swapaxes(U, 1, 2).astype(jnp.float32)       # (N, k, in)
    n_out, n_in, n_k = out_d // bo, in_d // bi, kd // bk
    kernel = functools.partial(_v_kernel_left, frac=frac, norm=norm,
                               eps=eps, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(N, n_out, n_in, n_k),
        in_specs=[
            pl.BlockSpec((None, bo, bk), lambda i, o, j, k: (i, o, k)),  # B
            pl.BlockSpec((None, bk, bi), lambda i, o, j, k: (i, k, j)),  # Uᵀ
            pl.BlockSpec((bo, bi), lambda i, o, j, k: (o, j)),       # W (out)
            pl.BlockSpec((None, bo, bi), lambda i, o, j, k: (i, o, j)),  # V
        ],
        out_specs=pl.BlockSpec((None, bo, bi), lambda i, o, j, k: (i, o, j)),
        out_shape=jax.ShapeDtypeStruct(V.shape, V.dtype),
        scratch_shapes=[pltpu.VMEM((bo, bi), jnp.float32)],
        interpret=interpret,
    )(B, UT, W, V)


# --------------------------------------------------------------------------
# stacked-layer variants: the scan-layer axis L rides the grid outermost
# (grid (L, N, n_out, n_in, n_k)), one launch per leaf covers all layers
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("frac", "norm", "eps",
                                             "bo", "bi", "bk",
                                             "interpret"))
def maecho_v_update_stacked(W, V, P, *, frac: float, norm: bool = False,
                            eps: float = 1e-12, bo: int = 128,
                            bi: int = 128, bk: int = 128,
                            interpret: bool = True):
    """W: (L, out, in) updated global; V: (N, L, out, in);
    P: (N, L, in, in).  Returns the (N, L, out, in) Eq. 11 anchors
    from one launch.  ``norm=True`` needs bi = in_d, as per-layer."""
    L, out_d, in_d = W.shape
    N = V.shape[0]
    bo, bi, bk = min(bo, out_d), min(bi, in_d), min(bk, in_d)
    if norm:
        assert bi == in_d, "row-norm needs full rows: set bi = in_d"
    assert out_d % bo == 0 and in_d % bi == 0 and in_d % bk == 0, (
        "pad layer dims to block multiples")
    n_out, n_in, n_k = out_d // bo, in_d // bi, in_d // bk
    kernel = functools.partial(_v_kernel_dense, frac=frac, norm=norm,
                               eps=eps, n_k=n_k, off=1)
    return pl.pallas_call(
        kernel,
        grid=(L, N, n_out, n_in, n_k),
        in_specs=[
            pl.BlockSpec((None, bo, bk),
                         lambda l, i, o, j, k: (l, o, k)),          # W (red.)
            pl.BlockSpec((None, None, bo, bk),
                         lambda l, i, o, j, k: (i, l, o, k)),       # V
            pl.BlockSpec((None, None, bk, bi),
                         lambda l, i, o, j, k: (i, l, k, j)),       # P
            pl.BlockSpec((None, bo, bi),
                         lambda l, i, o, j, k: (l, o, j)),          # W (out)
            pl.BlockSpec((None, None, bo, bi),
                         lambda l, i, o, j, k: (i, l, o, j)),       # V
        ],
        out_specs=pl.BlockSpec((None, None, bo, bi),
                               lambda l, i, o, j, k: (i, l, o, j)),
        out_shape=jax.ShapeDtypeStruct(V.shape, V.dtype),
        scratch_shapes=[pltpu.VMEM((bo, bi), jnp.float32)],
        interpret=interpret,
    )(W, V, P, W, V)


@functools.partial(jax.jit, static_argnames=("frac", "norm", "eps",
                                             "bo", "bi", "bk",
                                             "interpret"))
def maecho_v_update_factored_stacked(W, V, U, s, *, frac: float,
                                     norm: bool = False,
                                     eps: float = 1e-12, bo: int = 128,
                                     bi: int = 128, bk: int = 128,
                                     interpret: bool = True):
    """Stacked factored Pₗᵢ = Uₗᵢ·diag(sₗᵢ)·Uₗᵢᵀ.
    U: (N, L, in, k); s: (N, L, k)."""
    from repro.kernels.maecho_gram import compressed_residual

    L, out_d, in_d = W.shape
    N, _, _, kd = U.shape
    bo, bi, bk = min(bo, out_d), min(bi, in_d), min(bk, kd)
    if norm:
        assert bi == in_d, "row-norm needs full rows: set bi = in_d"
    assert out_d % bo == 0 and in_d % bi == 0 and kd % bk == 0, (
        "pad layer dims / rank to block multiples")
    B = compressed_residual(W, V, U, s)                # (N, L, out, k)
    UT = jnp.swapaxes(U, 2, 3).astype(jnp.float32)     # (N, L, k, in)
    n_out, n_in, n_k = out_d // bo, in_d // bi, kd // bk
    kernel = functools.partial(_v_kernel_left, frac=frac, norm=norm,
                               eps=eps, n_k=n_k, off=1)
    return pl.pallas_call(
        kernel,
        grid=(L, N, n_out, n_in, n_k),
        in_specs=[
            pl.BlockSpec((None, None, bo, bk),
                         lambda l, i, o, j, k: (i, l, o, k)),       # B
            pl.BlockSpec((None, None, bk, bi),
                         lambda l, i, o, j, k: (i, l, k, j)),       # Uᵀ
            pl.BlockSpec((None, bo, bi),
                         lambda l, i, o, j, k: (l, o, j)),          # W (out)
            pl.BlockSpec((None, None, bo, bi),
                         lambda l, i, o, j, k: (i, l, o, j)),       # V
        ],
        out_specs=pl.BlockSpec((None, None, bo, bi),
                               lambda l, i, o, j, k: (i, l, o, j)),
        out_shape=jax.ShapeDtypeStruct(V.shape, V.dtype),
        scratch_shapes=[pltpu.VMEM((bo, bi), jnp.float32)],
        interpret=interpret,
    )(B, UT, W, V)


@functools.partial(jax.jit, static_argnames=("frac", "norm", "eps",
                                             "bo", "bi", "interpret"))
def maecho_v_update_diag_stacked(W, V, p, *, frac: float,
                                 norm: bool = False, eps: float = 1e-12,
                                 bo: int = 128, bi: int = 128,
                                 interpret: bool = True):
    """Stacked diagonal projectors.  p: (N, L, in)."""
    L, out_d, in_d = W.shape
    N = V.shape[0]
    bo, bi = min(bo, out_d), min(bi, in_d)
    if norm:
        assert bi == in_d, "row-norm needs full rows: set bi = in_d"
    assert out_d % bo == 0 and in_d % bi == 0, (
        "pad layer dims to block multiples")
    p4 = p.reshape(N, L, 1, in_d)
    kernel = functools.partial(_v_diag_kernel, frac=frac, norm=norm,
                               eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(L, N, out_d // bo, in_d // bi),
        in_specs=[
            pl.BlockSpec((None, bo, bi),
                         lambda l, i, o, j: (l, o, j)),             # W
            pl.BlockSpec((None, None, bo, bi),
                         lambda l, i, o, j: (i, l, o, j)),          # V
            pl.BlockSpec((None, None, 1, bi),
                         lambda l, i, o, j: (i, l, 0, j)),          # p
        ],
        out_specs=pl.BlockSpec((None, None, bo, bi),
                               lambda l, i, o, j: (i, l, o, j)),
        out_shape=jax.ShapeDtypeStruct(V.shape, V.dtype),
        interpret=interpret,
    )(W, V, p4)


def _v_diag_kernel(w_ref, v_ref, p_ref, out_ref, *, frac, norm, eps):
    dj = (w_ref[...] - v_ref[...]).astype(jnp.float32)   # (bo, bi)
    p = p_ref[...].astype(jnp.float32)                   # (1, bi)
    u = dj * (1.0 - frac * p)
    if norm:
        u = _apply_norm(u, eps)
    out_ref[...] = (v_ref[...].astype(jnp.float32) + u
                    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("frac", "norm", "eps",
                                             "bo", "bi", "interpret"))
def maecho_v_update_diag(W, V, p, *, frac: float, norm: bool = False,
                         eps: float = 1e-12, bo: int = 128,
                         bi: int = 128, interpret: bool = True):
    """Diagonal projectors.  p: (N, in)."""
    out_d, in_d = W.shape
    N = V.shape[0]
    bo, bi = min(bo, out_d), min(bi, in_d)
    if norm:
        assert bi == in_d, "row-norm needs full rows: set bi = in_d"
    assert out_d % bo == 0 and in_d % bi == 0, (
        "pad layer dims to block multiples")
    p3 = p.reshape(N, 1, in_d)
    kernel = functools.partial(_v_diag_kernel, frac=frac, norm=norm,
                               eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N, out_d // bo, in_d // bi),
        in_specs=[
            pl.BlockSpec((bo, bi), lambda i, o, j: (o, j)),          # W
            pl.BlockSpec((None, bo, bi), lambda i, o, j: (i, o, j)),  # V
            pl.BlockSpec((None, 1, bi), lambda i, o, j: (i, 0, j)),   # p
        ],
        out_specs=pl.BlockSpec((None, bo, bi), lambda i, o, j: (i, o, j)),
        out_shape=jax.ShapeDtypeStruct(V.shape, V.dtype),
        interpret=interpret,
    )(W, V, p3)
