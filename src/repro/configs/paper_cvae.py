"""The paper's conditional VAE (decoder 30 -> 256 -> 512 -> 784), §7.1."""
from repro.fl.models import CVAE_SPEC, PaperModelSpec


def config() -> PaperModelSpec:
    return CVAE_SPEC


def smoke_config() -> PaperModelSpec:
    import dataclasses
    return dataclasses.replace(CVAE_SPEC, latent=8, cvae_hidden=(32, 64))
