"""Serving loop: window helpers + continuous-batching token parity.

The continuous-batching loop (``launch/serve.py --arrival``) must emit
exactly the tokens the lockstep fixed-batch loop emits per request —
admission order, slot reuse, batch-1 prefill insertion and the
bucketed live-window crop must all be invisible to the outputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import (live_bucket, pad_kv_to_window,
                                round_window, run_arrival, run_fixed)


def test_round_window():
    assert round_window(1) == 128
    assert round_window(128) == 128
    assert round_window(129) == 256
    assert round_window(1000) == 1024


def test_live_bucket():
    assert live_bucket(1, 4096) == 256          # floor 2 x block
    assert live_bucket(256, 4096) == 256
    assert live_bucket(257, 4096) == 512
    assert live_bucket(900, 4096) == 1024
    assert live_bucket(5000, 4096) == 4096      # capped at the window


def test_pad_kv_to_window_pads_only_ring_leaves():
    cache = {
        "k": jnp.ones((2, 3, 16, 4, 8)),
        "v": jnp.ones((2, 3, 16, 4, 8)),
        "xk": jnp.ones((2, 3, 50, 4, 8)),       # cross-attn: untouched
        "nested": {"k": jnp.ones((4, 1, 16, 2, 8))},
    }
    out = pad_kv_to_window(cache, 64)
    assert out["k"].shape == (2, 3, 64, 4, 8)
    assert out["v"].shape == (2, 3, 64, 4, 8)
    assert out["xk"].shape == (2, 3, 50, 4, 8)
    assert out["nested"]["k"].shape == (4, 1, 64, 2, 8)
    # padded slots are zeros, original slots preserved
    np.testing.assert_array_equal(np.asarray(out["k"][:, :, :16]), 1.0)
    np.testing.assert_array_equal(np.asarray(out["k"][:, :, 16:]), 0.0)


@pytest.mark.slow
def test_arrival_matches_fixed_batch_tokens():
    """Per-request tokens from the slot loop == the fixed-batch run,
    with requests trickling in mid-decode and slots being reused."""
    from repro.configs import get_smoke_config
    from repro.models.zoo import get_model

    cfg = get_smoke_config("qwen2-0.5b")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    R, P, gen = 5, 12, 6
    prompts = jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab, size=(R, P)),
        jnp.int32)

    fixed, _ = run_fixed(cfg, model, params, prompts, gen)
    outs, stats = run_arrival(cfg, model, params, prompts, gen,
                              slots=2, arrival_every=2)
    assert stats["decode_steps"] >= gen - 1     # ran past one batch
    for r in range(R):
        assert len(outs[r]) == gen
        np.testing.assert_array_equal(
            np.asarray(fixed[r]), np.asarray(outs[r], np.int32))
