"""Mesh-sharded aggregation scaling (ISSUE 3 tentpole).

Times the out-dim-sharded MA-Echo pipeline at 1/2/4/8 host devices:
the Gram phase alone (``ops.maecho_sharded_gram`` — residual tiles +
partial contraction + one psum) and a full ``maecho_aggregate`` with
``backend="sharded"``.  The forced host-device count must be fixed
before jax initializes, so every device count runs in its own
subprocess; the parent collects one JSON line per child.

On this CPU container the "devices" share one socket, so the curve
records interpret-mode *overhead* scaling, not the TPU speedup — the
row trajectory still gates regressions in the sharded dispatch path
(padding, shard_map plumbing, psum placement), and each child asserts
Gram parity against the jnp oracle.  Rows land in
``BENCH_sharded_agg.json`` via ``benchmarks.run``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import row

_CHILD = r"""
import json, os, sys
n, out_d, in_d, N, tau = map(int, sys.argv[1:6])
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={n} "
    + os.environ.get("XLA_FLAGS", ""))
import time
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.core.maecho import MAEchoConfig, maecho_aggregate
from repro.kernels import ops, ref

assert len(jax.devices()) >= n, (len(jax.devices()), n)
mesh = Mesh(np.asarray(jax.devices()[:n]), ("data",))
k = jax.random.PRNGKey(0)
W = jax.random.normal(k, (out_d, in_d)) * 0.3
V = jax.random.normal(jax.random.fold_in(k, 1), (N, out_d, in_d)) * 0.3
U = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(k, 2),
                                    (N, in_d, 16)))[0]
s = jax.random.uniform(jax.random.fold_in(k, 3), (N, 16))
P = jnp.einsum("nik,nk,njk->nij", U, s, U)          # dense PSD


def best_of(fn, reps=3):
    out = fn()
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
    best = 1e30
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


gram = jax.jit(lambda W, V, P: ops.maecho_sharded_gram(
    W, V, P, mesh=mesh, axis="data")[0])
G, gram_us = best_of(lambda: gram(W, V, P))
Gr = ref.maecho_gram_ref(W, V, P)
rel = float(jnp.max(jnp.abs(G - Gr)) / jnp.max(jnp.abs(Gr)))
assert rel < 1e-3, f"sharded Gram diverged from oracle: rel={rel}"

clients = [{"W": V[i]} for i in range(N)]
projs = [{"W": P[i]} for i in range(N)]
cfg = MAEchoConfig(tau=tau, eta=0.5, qp_iters=60)
_, agg_us = best_of(lambda: maecho_aggregate(
    clients, projs, cfg, backend="sharded", mesh=mesh))
print(json.dumps({"gram_us": gram_us, "agg_us": agg_us,
                  "match": rel < 1e-3}))
"""


def _child(n: int, out_d: int, in_d: int, N: int, tau: int) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _CHILD, str(n), str(out_d), str(in_d),
         str(N), str(tau)],
        env=env, capture_output=True, text=True, timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(
            f"sharded_agg child (devices={n}) failed:\n"
            f"{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def run(quick: bool = False):
    out_d, in_d, N, tau = ((1024, 256, 3, 2) if quick
                           else (4096, 256, 4, 2))
    devices = [1, 2] if quick else [1, 2, 4, 8]
    base = {}
    for n in devices:
        res = _child(n, out_d, in_d, N, tau)
        base.setdefault("gram", res["gram_us"])
        base.setdefault("agg", res["agg_us"])
        tag = f"out{out_d}x{in_d}_N{N}"
        row(f"sharded_agg/gram_d{n}_{tag}", res["gram_us"],
            f"vs_d1={base['gram'] / max(res['gram_us'], 1):.2f}x;"
            f"match={res['match']}")
        row(f"sharded_agg/agg_tau{tau}_d{n}_{tag}", res["agg_us"],
            f"vs_d1={base['agg'] / max(res['agg_us'], 1):.2f}x")


if __name__ == "__main__":
    run()
