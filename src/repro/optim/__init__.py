from repro.optim.optimizers import (  # noqa: F401
    Optimizer, sgd, adamw, cosine_schedule, constant_schedule,
    clip_by_global_norm,
)
