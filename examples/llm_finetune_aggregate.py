"""Cross-silo LLM fine-tuning + MA-Echo aggregation (the paper's
technique as a first-class feature of the LLM framework).

Two silos fine-tune the same (reduced) qwen2-0.5b checkpoint on
different synthetic token distributions; the server aggregates with
layer-wise projection matrices captured by the feature probe —
including the diag token-support rule on the embedding.

  PYTHONPATH=src python examples/llm_finetune_aggregate.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.maecho import MAEchoConfig
from repro.core.aggregators import fedavg
from repro.data.synthetic import lm_token_batches
from repro.fl.llm_adapter import aggregate_llm, build_projections
from repro.models.zoo import get_model
from repro.optim import adamw


def finetune(model, params, vocab, *, seed, steps=60, batch=8, seq=64):
    opt = adamw(1e-3)
    state = opt.init(params)
    step_fn = jax.jit(model.make_train_step(opt))
    for t, b in enumerate(lm_token_batches(vocab, batch, seq, steps,
                                           seed=seed)):
        params, state, loss = step_fn(params, state, b, jnp.int32(t))
    return params, float(loss)


def ppl(model, params, vocab, seed, n=5):
    tot = 0.0
    for b in lm_token_batches(vocab, 8, 64, n, seed=seed):
        tot += float(model.loss_fn(params, b))
    return jnp.exp(tot / n)


def main():
    cfg = get_smoke_config("qwen2-0.5b")
    model = get_model(cfg)
    base = model.init_params(jax.random.PRNGKey(0))

    # two silos: different token "domains" (different markov seeds)
    silos, projs = [], []
    for i, dom in enumerate((101, 202)):
        p, loss = finetune(model, base, cfg.vocab, seed=dom)
        print(f"silo {i}: final local loss {loss:.3f}")
        probe = list(lm_token_batches(cfg.vocab, 8, 64, 2, seed=dom))
        silos.append(p)
        projs.append(build_projections(cfg, p, probe))

    candidates = {
        "fedavg": fedavg(silos),
        "maecho": aggregate_llm(cfg, silos, projs,
                                MAEchoConfig(tau=15, eta=0.5, mu=20.0)),
    }
    print(f"{'model':10s} {'ppl@dom0':>9s} {'ppl@dom1':>9s}")
    for i, p in enumerate(silos):
        print(f"silo{i:<6d} {ppl(model, p, cfg.vocab, 101):9.2f} "
              f"{ppl(model, p, cfg.vocab, 202):9.2f}")
    for name, p in candidates.items():
        print(f"{name:10s} {ppl(model, p, cfg.vocab, 101):9.2f} "
              f"{ppl(model, p, cfg.vocab, 202):9.2f}")


if __name__ == "__main__":
    main()
