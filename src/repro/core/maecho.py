"""MA-Echo — Algorithm 1 of the paper, as a composable JAX op.

Operates on *pytrees of layers*: each client contributes a pytree of
weight leaves plus a structurally matching pytree of projection leaves.
Faithful to the paper:

  W⁽⁰⁾ = init (vanilla average by default);  Vᵢ = Wᵢ
  repeat τ times, per layer l:
      Rᵢ  = (W − Vᵢ) Pᵢ                    (residual in client i's row space)
      α*  = argmin ½‖Σᵢ 2αᵢ Rᵢ‖²  on the capped simplex   (Eq. 6)
      W  += η · ( −Σᵢ 2αᵢ* Rᵢ )                            (Eq. 7)
      Vᵢ += Norm( (W − Vᵢ)(I − μ/(1+μ) Pᵢ) )              (Eq. 11)

Projection leaves may be:
  - 2-D (d_in, d_in): full projector (paper's form);
  - 1-D matching the in-axis: diagonal projector (used for embedding
    tables where the input space is the one-hot vocabulary — P is the
    client's token-support indicator);
  - scalar 1.0: full-rank "input is always live" projector, the bias /
    norm-parameter rule (DESIGN.md §4);
  - any of the above with a leading stacked-layer axis L, matching a
    weight leaf (L, …) — the scan-over-layers LLM layout.  The QP is
    then solved per scanned layer (vmap), exactly like the paper's
    per-layer loop.

Weight-leaf convention: ``convention="oi"`` (paper: W is (out, in), the
MLP/CNN models) or ``"io"`` (the LLM zoo: x @ W, W is (in, out)).

Backends — the ``backend`` argument of :func:`maecho_aggregate`:

  - ``"oracle"`` (default): the reference jnp path below.  Each outer
    iteration materializes the full (N, out, in) fp32 residual tensor
    Rᵢ = (W − Vᵢ)Pᵢ twice (once for the Eq. 6/7 Gram+update, once
    re-projected for Eq. 11) — 2·N·out·in fp32 of HBM traffic per
    layer per iteration that exists only to be contracted away.
  - ``"kernel"``: the fused streaming pipeline.  Eligible leaves (2-D
    weights, with or without leading stacked-layer axes) run three
    Pallas passes per iteration — ``maecho_gram`` (Eq. 6 Gram,
    residual tiles formed in VMEM and contracted on the fly),
    ``maecho_update`` (Eq. 7) and ``maecho_v_update`` (Eq. 11) — so
    no residual tensor is ever resident in HBM.  A stacked leaf's
    layer axes are flattened into the kernel grid's outermost
    dimension (one launch per pass covers all L scanned layers — the
    ``*_stacked`` kernels); factored ``{"U", "s"}`` projectors stay
    factored through the compute: the (N, [L,] out, k) compressed
    residual replaces the full one and every GEMM chain drops from
    O(out·in²) to O(out·in·k).  Ineligible leaves (1-D biases, shapes
    below one tile) fall back to the oracle — dispatch happens at
    trace time, the whole τ-loop still jits as one program, and the
    fallback is surfaced once via ``ops.fallback_warn``.
  - ``"auto"``: ``"kernel"`` for leaves big enough to tile
    (min trailing dim ≥ 128), ``"oracle"`` otherwise.
  - ``"sharded"``: the mesh-sharded pipeline.  Eligible leaves (2-D
    weights, stacked or not, out-dim tile count divisible by the
    mesh-axis size — ``ops.sharded_ok``) run the streaming gram/apply
    under ``shard_map`` over ``MAEchoConfig.mesh_axis``: each device
    owns an out-row shard, forms only its residual tiles, and ONE
    ``psum`` per leaf per outer iteration reconstructs the Gram —
    (N, N), or the whole (L, N, N) stack for a stacked leaf whose
    layer axis rides the grid; the stacked QP solve stays global and
    the Eq. 7/11 applies run purely on the owned rows
    (compressed-residual reuse intact).  Ineligible leaves degrade to
    the single-device ``"auto"`` dispatch.  Pass the mesh via
    ``maecho_aggregate(..., mesh=...)`` (default: a 1-D mesh over
    every visible device).

Ragged participation (``maecho_aggregate(..., client_mask=...)``): an
optional per-leaf boolean client mask rides the batched QP's validity
masking — masked-out clients get exactly α = 0 (their residuals never
touch the Eq. 7 update), their anchors Vᵢ are frozen, and the result
matches aggregating the participating subset alone (same init point).

The QP and the padding logic (``repro.kernels.ops._pad_to``, zero
padding is exact for all three passes) are shared between backends;
``REPRO_PALLAS_INTERPRET`` selects interpret-mode kernel execution
(this container) vs real TPU lowering.

Batched QP (``MAEchoConfig.qp_batched``, default on): each outer
iteration runs in three phases — every leaf (and every scanned layer
of a stacked leaf) first emits its (N, N) Gram into one stacked
(L, N, N) tensor, a **single** vmapped PGD solve
(``qp.solve_qp_batched``) produces all τ vectors at once, and the
α rows are scattered back through the per-leaf Eq. 7 / Eq. 11 updates
(reusing the residual / compressed-residual context computed in the
gram phase).  ``qp_batched=False`` restores the sequential
one-PGD-per-leaf loop — same math, L solves instead of one.

Memory trade-off: the batched path keeps every leaf's reuse context
(on the oracle backend, the (N, out, in) fp32 residual) live across
the stacked solve, so peak residency grows from one leaf's residual
to ~N× the whole model in fp32.  Fine for the paper-scale models
this τ-loop targets; for LLM-scale trees where that doesn't fit, set
``qp_batched=False`` (sequential frees each leaf's residual before
the next gram) or use the factored/kernel paths whose contexts are
the (N, out, k) compressed residuals.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import qp as qp_mod
from repro.utils import trees

Pytree = Any


@dataclasses.dataclass(frozen=True)
class MAEchoConfig:
    tau: int = 30                 # outer iterations
    eta: float = 1.0              # step size on W
    C: float = 1.0                # simplex cap (paper: C ∈ [1/N, 1])
    mu: float = 1.0               # Eq. 8 penalty; factor μ/(1+μ)
    norm: bool = False            # Norm(·) row-normalisation of V updates
    qp_iters: int = 200
    init: str = "average"         # average | first | random
    eps: float = 1e-12
    qp_batched: bool = True       # one stacked PGD solve per outer iter
    mesh_axis: str = "data"       # shard_map axis for backend="sharded"
    # kernel tile edge for the (non-sharded) streaming pipeline;
    # 0 = ops.DEFAULT_BLOCK (128, the TPU-safe MXU tile).  Bigger
    # blocks shrink the grid — the interpret-mode benches use 512 to
    # amortize per-step interpreter overhead; on TPU stay within VMEM
    # (the gram rstore is N·bo·bi fp32).  The sharded pipeline keeps
    # DEFAULT_BLOCK (its out-padding granularity is block × axis_size).
    kernel_block: int = 0


# --------------------------------------------------------------------------
# per-leaf algebra
# --------------------------------------------------------------------------
def _apply_P(delta, P, convention: str):
    """delta·P respecting the in-axis convention and P's kind.

    P kinds: scalar (bias rule), 1-D diag (embedding token support),
    2-D full matrix, or FACTORED {"U": (in, k), "s": (k,)} with
    P = U·diag(s)·Uᵀ — the beyond-paper optimisation (EXPERIMENTS.md
    §Perf H3): the Eq. 7 GEMM chain drops from O(out·in²) to
    O(out·in·k), and communication from in² to in·(k+1) (paper Table 6
    shows the projectors are low-rank; we keep them factored through
    the *compute*, not just the wire).
    """
    if isinstance(P, dict):                 # factored projector
        U = P["U"]
        s = P["s"]
        if delta.ndim == 1:
            return ((delta @ U) * s) @ U.T
        if convention == "oi":
            return ((delta @ U) * s) @ U.T  # (out,k)·(k)·(k,in)
        return U @ (s[:, None] * (U.T @ delta))
    if P.ndim == 0:                         # full projector (bias rule)
        return delta * P
    if P.ndim == 1:                         # diagonal projector on in-axis
        if delta.ndim == 1:
            return delta * P
        return delta * (P[None, :] if convention == "oi" else P[:, None])
    # full matrix projector
    if delta.ndim == 1:
        return delta @ P
    if convention == "oi":
        return delta @ P                    # (out,in)@(in,in)
    return P @ delta                        # (in,in)@(in,out)


def _qp_alpha(G, cfg: MAEchoConfig, mask=None):
    """Eq. 6 dual QP for the sequential (per-leaf) path.  Delegates to
    ``qp.solve_qp`` — the same ``_pgd_masked`` body the batched solver
    vmaps, so batched/sequential parity is structural, not maintained
    by hand.  (The jitted wrapper traces inline under the enclosing
    jit; the whole aggregation still compiles as one program.)
    ``mask`` is the leaf's participation mask (ragged cohorts)."""
    return qp_mod.solve_qp(G, cfg.C, iters=cfg.qp_iters, mask=mask)


def _kernel_eligible(W, P, levels: int = 0) -> bool:
    """Leaf shapes the fused pipelines handle: a 2-D weight (plus
    ``levels`` leading stacked-layer axes) with a scalar / diagonal /
    dense / factored projector whose kind axes shift by the same
    ``levels``."""
    if getattr(W, "ndim", 0) != 2 + levels:
        return False
    if isinstance(P, dict):
        return (set(P) == {"U", "s"}
                and getattr(P["U"], "ndim", 0) == 3 + levels)
    return getattr(P, "ndim", -1) in (1 + levels, 2 + levels, 3 + levels)


def _kernel_dims(W, convention: str) -> tuple:
    """(out_d, in_d) of a leaf in the "oi"-native kernel layout — the
    trailing two axes, swapped for "io" (stack axes don't matter)."""
    out_d, in_d = W.shape[-2:]
    return (out_d, in_d) if convention == "oi" else (in_d, out_d)


def _use_kernel(W, P, backend: str, levels: int = 0) -> bool:
    """Does this leaf take the fused streaming pipeline?  Must agree
    between the gram and apply halves — both recompute it from the
    same static shapes.  ``backend="sharded"`` lands here for leaves
    that failed :func:`_use_sharded` — they take the "auto" rule (the
    single-device kernel path when big enough to tile)."""
    if backend == "oracle" or not _kernel_eligible(W, P, levels):
        return False
    from repro.kernels.ops import DEFAULT_BLOCK
    return backend == "kernel" or min(W.shape[-2:]) >= DEFAULT_BLOCK


def _use_sharded(W, P, backend: str, mesh, convention: str,
                 axis, levels: int = 0) -> bool:
    """Does this leaf take the out-dim mesh-sharded pipeline?  Needs
    ``backend="sharded"``, a mesh that actually carries the configured
    axis, a kernel-eligible leaf (2-D plus ``levels`` stack axes), and
    even block-granular divisibility of the (kernel-layout) out-dim
    over the axis (``ops.sharded_ok`` — the sharding rules' ``_ok``
    contract; it warns once on the fallback).  Anything else falls
    back through :func:`_use_kernel` to the single-device path.
    Static shapes only — the gram and apply halves must agree."""
    if backend != "sharded" or mesh is None \
            or not _kernel_eligible(W, P, levels):
        return False
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    if any(n not in mesh.shape for n in names):
        return False               # shard_map would KeyError the name
    from repro.kernels import ops
    out_d, in_d = _kernel_dims(W, convention)
    return ops.sharded_ok(out_d, in_d, ops.axis_size_of(mesh, axis),
                          warn=True)


def _stacked_route(W, P, cfg: MAEchoConfig, convention: str,
                   backend: str, mesh, levels: int):
    """Compute path of a stacked leaf: ``"sharded"`` | ``"kernel"`` |
    ``None`` (the vmapped-oracle fallback).  The layer axes fold into
    the kernel grid, so eligibility is exactly the per-layer rule on
    the trailing (out, in) dims; an oracle fallback under a non-oracle
    backend is surfaced once via ``ops.fallback_warn``."""
    if _use_sharded(W, P, backend, mesh, convention, cfg.mesh_axis,
                    levels):
        return "sharded"
    if _use_kernel(W, P, backend, levels):
        return "kernel"
    if backend not in ("oracle", "auto"):
        # "auto" documents 'oracle otherwise' — only a FORCED fast
        # path degrading is silent-degradation worth a warning (the
        # 2-D dispatch draws the same line)
        from repro.kernels import ops
        ops.fallback_warn(
            f"stacked leaf (shape={tuple(W.shape)}, levels={levels}) "
            f"ineligible for backend={backend!r}: falling back to the "
            f"vmapped jnp oracle")
    return None


def _flatten_stack(W, V, P, levels: int):
    """Collapse ``levels`` leading stacked-layer axes into one flat L
    axis for the stacked kernel grid.  Returns ``(Wf, Vf, Pf, lead)``
    with Wf (L, out, in), Vf (N, L, out, in), Pf stacked per kind, and
    ``lead`` the original leading shape for un-flattening."""
    lead = W.shape[:levels]
    Wf = W.reshape((-1,) + W.shape[levels:])
    Vf = V.reshape(V.shape[:1] + (-1,) + V.shape[1 + levels:])

    def flat_p(x):
        return x.reshape(x.shape[:1] + (-1,) + x.shape[1 + levels:])

    Pf = ({k: flat_p(v) for k, v in P.items()} if isinstance(P, dict)
          else flat_p(P))
    return Wf, Vf, Pf, lead


def _to_kernel_layout(W, V, P, convention: str, levels: int = 0):
    """The kernel pipelines are "oi"-native; "io" leaves are transposed
    around the call (XLA fuses the transposes into the kernels' operand
    loads).  Shared by the streaming and sharded gram halves — one copy
    of the layout contract; stacked leaves transpose the trailing two
    axes only."""
    if convention != "io":
        return W, V, P
    # oracle applies delta·P from the left for "io": (PᵢΔ)ᵀ = ΔᵀPᵢᵀ
    Pk = jnp.swapaxes(P, -1, -2) if (not isinstance(P, dict)
                                     and P.ndim == 3 + levels) else P
    return jnp.swapaxes(W, -1, -2), jnp.swapaxes(V, -1, -2), Pk


def _block_of(cfg: MAEchoConfig) -> int:
    from repro.kernels.ops import DEFAULT_BLOCK

    return cfg.kernel_block or DEFAULT_BLOCK


def _leaf_gram_kernel(W, V, P, cfg: MAEchoConfig, convention: str):
    """Gram half of the fused streaming pipeline: the Eq. 6 Gram plus
    the padded-operand reuse context (padding/kind dispatch and the
    factored-path compressed-residual sharing live in
    ``ops.maecho_streaming_gram``)."""
    from repro.kernels import ops

    Wk, Vk, Pk = _to_kernel_layout(W, V, P, convention)
    return ops.maecho_streaming_gram(Wk, Vk, Pk, block=_block_of(cfg))


def _leaf_apply_kernel(alpha, ctx, cfg: MAEchoConfig, convention: str):
    """Update half of the fused streaming pipeline: Eq. 7 + Eq. 11 on
    the context from :func:`_leaf_gram_kernel`."""
    from repro.kernels import ops

    W_new, V_new = ops.maecho_streaming_apply(
        alpha, ctx, eta=cfg.eta, frac=cfg.mu / (1.0 + cfg.mu),
        norm=cfg.norm, eps=cfg.eps, block=_block_of(cfg))
    if convention == "io":
        return W_new.T, jnp.swapaxes(V_new, 1, 2)
    return W_new, V_new


def _leaf_gram_sharded(W, V, P, cfg: MAEchoConfig, convention: str,
                       mesh):
    """Gram half of the mesh-sharded pipeline: the shared "oi"-native
    layout contract (:func:`_to_kernel_layout`), with the out-rows
    shard_map'd over ``cfg.mesh_axis`` (one Gram psum)."""
    from repro.kernels import ops

    Wk, Vk, Pk = _to_kernel_layout(W, V, P, convention)
    return ops.maecho_sharded_gram(Wk, Vk, Pk, mesh=mesh,
                                   axis=cfg.mesh_axis)


def _leaf_apply_sharded(alpha, ctx, cfg: MAEchoConfig, convention: str,
                        mesh):
    """Update half of the mesh-sharded pipeline: Eq. 7 + Eq. 11 run
    row-local on each device's owned shard — no collectives."""
    from repro.kernels import ops

    W_new, V_new = ops.maecho_sharded_apply(
        alpha, ctx, mesh=mesh, axis=cfg.mesh_axis, eta=cfg.eta,
        frac=cfg.mu / (1.0 + cfg.mu), norm=cfg.norm, eps=cfg.eps)
    if convention == "io":
        return W_new.T, jnp.swapaxes(V_new, 1, 2)
    return W_new, V_new


def _leaf_gram_stacked(W, V, P, cfg: MAEchoConfig, convention: str,
                       route: str, mesh, levels: int):
    """Gram half for a stacked leaf on the kernel or sharded pipeline:
    the ``levels`` leading layer axes are flattened into the kernel
    grid's outer dimension — ONE launch (and, sharded, ONE psum
    carrying the (L, N, N) stack) covers every scanned layer.  Returns
    ``(G, ctx)`` with G carrying the original leading axes before its
    trailing (N, N), matching the oracle-vmap layout."""
    from repro.kernels import ops

    Wf, Vf, Pf, lead = _flatten_stack(W, V, P, levels)
    Wk, Vk, Pk = _to_kernel_layout(Wf, Vf, Pf, convention, levels=1)
    if route == "sharded":
        G, ctx = ops.maecho_sharded_gram_stacked(Wk, Vk, Pk, mesh=mesh,
                                                 axis=cfg.mesh_axis)
    else:
        G, ctx = ops.maecho_streaming_gram_stacked(
            Wk, Vk, Pk, block=_block_of(cfg))
    return G.reshape(lead + G.shape[-2:]), ("stk", route, lead, ctx)


def _leaf_apply_stacked(alpha, ctx, cfg: MAEchoConfig,
                        convention: str, mesh):
    """Update half for a stacked leaf: per-layer Eq. 7 + Eq. 11 from
    the flattened-grid context.  ``alpha`` carries the leaf's leading
    stack axes before its trailing N (the QP batch layout)."""
    from repro.kernels import ops

    _, route, lead, inner = ctx
    af = alpha.reshape((-1,) + alpha.shape[-1:])
    kw = dict(eta=cfg.eta, frac=cfg.mu / (1.0 + cfg.mu), norm=cfg.norm,
              eps=cfg.eps)
    if route == "sharded":
        Wn, Vn = ops.maecho_sharded_apply_stacked(
            af, inner, mesh=mesh, axis=cfg.mesh_axis, **kw)
    else:
        Wn, Vn = ops.maecho_streaming_apply_stacked(
            af, inner, block=_block_of(cfg), **kw)
    if convention == "io":
        Wn, Vn = jnp.swapaxes(Wn, -1, -2), jnp.swapaxes(Vn, -1, -2)
    return (Wn.reshape(lead + Wn.shape[-2:]),
            Vn.reshape(Vn.shape[:1] + lead + Vn.shape[-2:]))


def _leaf_gram_oracle(W, V, P, convention: str):
    """Reference gram half: materializes the residual once and returns
    it as the reuse context for :func:`_leaf_apply_oracle` (the same
    tensor the fused step shared between its Gram and Eq. 7)."""
    N = V.shape[0]
    R = jax.vmap(lambda v, p: _apply_P(W - v, p, convention))(V, P)  # (N, ...)
    Rf = R.reshape(N, -1).astype(jnp.float32)
    return Rf @ Rf.T, R                                            # (N, N)


def _leaf_apply_oracle(W, V, P, R, alpha, cfg: MAEchoConfig,
                       convention: str):
    """Reference update half: Eq. 7 from the cached residual, then the
    Eq. 11 anchor update."""
    D = -2.0 * jnp.tensordot(alpha, R.astype(jnp.float32), axes=(0, 0))
    W_new = (W.astype(jnp.float32) + cfg.eta * D).astype(W.dtype)

    # Eq. 11: V_i += Norm((W' − V_i)(I − μ/(1+μ) P_i))
    frac = cfg.mu / (1.0 + cfg.mu)

    def v_update(v, p):
        delta = W_new - v
        U = delta - frac * _apply_P(delta, p, convention)
        if cfg.norm:
            ax = -1 if convention == "oi" else 0
            nrm = jnp.linalg.norm(
                U.astype(jnp.float32), axis=ax, keepdims=True)
            U = U / jnp.maximum(nrm, cfg.eps).astype(U.dtype)
        return v + U

    V_new = jax.vmap(v_update)(V, P)
    return W_new, V_new


def _leaf_step(W, V, P, cfg: MAEchoConfig, convention: str,
               backend: str = "oracle", mesh=None, mask=None):
    """One Algorithm-1 iteration for a single layer leaf (the
    sequential-QP path: gram → own PGD solve → apply).

    W: (...,);  V: (N, ...);  P: (N, [in, in] | [in] | []).
    Returns (W', V').
    """
    if _use_sharded(W, P, backend, mesh, convention, cfg.mesh_axis):
        G, ctx = _leaf_gram_sharded(W, V, P, cfg, convention, mesh)
        return _leaf_apply_sharded(_qp_alpha(G, cfg, mask), ctx, cfg,
                                   convention, mesh)
    if _use_kernel(W, P, backend):
        G, ctx = _leaf_gram_kernel(W, V, P, cfg, convention)
        return _leaf_apply_kernel(_qp_alpha(G, cfg, mask), ctx, cfg,
                                  convention)
    G, R = _leaf_gram_oracle(W, V, P, convention)
    return _leaf_apply_oracle(W, V, P, R, _qp_alpha(G, cfg, mask), cfg,
                              convention)


def _dispatch_leaf(W, V, P, cfg: MAEchoConfig, convention: str,
                   levels: int = 0, backend: str = "oracle", mesh=None,
                   mask=None):
    """``levels`` leading stacked-layer axes fold into the kernel grid
    when the leaf is pipeline-eligible (one launch covers all scanned
    layers) and are vmapped over the oracle otherwise; either way the
    QP is solved per scanned layer, matching the paper's per-layer
    loop.  The participation mask is shared by every scanned layer of
    a leaf."""
    if levels > 0:
        route = _stacked_route(W, P, cfg, convention, backend, mesh,
                               levels)
        if route is not None:
            G, ctx = _leaf_gram_stacked(W, V, P, cfg, convention,
                                        route, mesh, levels)
            Gf = G.reshape((-1,) + G.shape[-2:])
            alpha = jax.vmap(lambda g: _qp_alpha(g, cfg, mask))(Gf)
            alpha = alpha.reshape(G.shape[:-2] + alpha.shape[-1:])
            return _leaf_apply_stacked(alpha, ctx, cfg, convention,
                                       mesh)
        # V/P: (N, L, ...) -> vmap over L (axis 1 of V/P, axis 0 of W)
        return jax.vmap(
            lambda w, v, p: _dispatch_leaf(w, v, p, cfg, convention,
                                           levels - 1, "oracle",
                                           mask=mask),
            in_axes=(0, 1, 1), out_axes=(0, 1))(W, V, P)
    return _leaf_step(W, V, P, cfg, convention, backend, mesh, mask)


# --------------------------------------------------------------------------
# batched QP: gram/apply leaf dispatch around one stacked PGD solve
# --------------------------------------------------------------------------
def _leaf_gram(W, V, P, cfg: MAEchoConfig, convention: str,
               levels: int = 0, backend: str = "oracle", mesh=None):
    """Gram phase of the batched outer iteration.

    Returns ``(G, ctx)``: G carries any stacked-layer axes in front of
    its trailing (N, N) — the caller flattens those into the QP batch
    axis — and ``ctx`` is the per-leaf reuse payload for
    :func:`_leaf_apply` (the oracle residual, or the kernel/sharded
    pipeline's padded-operand context).  An eligible stacked leaf
    folds its layer axes into the kernel grid (one launch, and on the
    sharded route one (L, N, N) psum, for all L scanned layers);
    ineligible ones vmap the oracle gram.  Either way a leaf with L
    scanned layers contributes L rows to the batch."""
    if levels > 0:
        route = _stacked_route(W, P, cfg, convention, backend, mesh,
                               levels)
        if route is not None:
            return _leaf_gram_stacked(W, V, P, cfg, convention, route,
                                      mesh, levels)
        return jax.vmap(
            lambda w, v, p: _leaf_gram(w, v, p, cfg, convention,
                                       levels - 1, "oracle"),
            in_axes=(0, 1, 1), out_axes=0)(W, V, P)
    if _use_sharded(W, P, backend, mesh, convention, cfg.mesh_axis):
        return _leaf_gram_sharded(W, V, P, cfg, convention, mesh)
    if _use_kernel(W, P, backend):
        return _leaf_gram_kernel(W, V, P, cfg, convention)
    return _leaf_gram_oracle(W, V, P, convention)


def _leaf_apply(W, V, P, ctx, alpha, cfg: MAEchoConfig,
                convention: str, levels: int = 0,
                backend: str = "oracle", mesh=None):
    """Apply phase of the batched outer iteration: scatter this leaf's
    τ rows of the stacked solve back through Eq. 7 / Eq. 11.  ``alpha``
    carries the leaf's stacked-layer axes in front of its trailing N,
    mirroring the gram layout."""
    if levels > 0:
        if isinstance(ctx, tuple) and len(ctx) == 4 and ctx[0] == "stk":
            return _leaf_apply_stacked(alpha, ctx, cfg, convention,
                                       mesh)
        return jax.vmap(
            lambda w, v, p, r, a: _leaf_apply(w, v, p, r, a, cfg,
                                              convention, levels - 1,
                                              "oracle"),
            in_axes=(0, 1, 1, 0, 0), out_axes=(0, 1))(W, V, P, ctx,
                                                      alpha)
    if _use_sharded(W, P, backend, mesh, convention, cfg.mesh_axis):
        return _leaf_apply_sharded(alpha, ctx, cfg, convention, mesh)
    if _use_kernel(W, P, backend):
        return _leaf_apply_kernel(alpha, ctx, cfg, convention)
    return _leaf_apply_oracle(W, V, P, ctx, alpha, cfg, convention)


# --------------------------------------------------------------------------
# full aggregation
# --------------------------------------------------------------------------
def default_projections(client_weights: list[Pytree]) -> list[Pytree]:
    """Scalar full projectors everywhere (degenerates MA-Echo toward a
    consensus pull; used when a leaf has no feature statistics)."""
    return [trees.tree_map(lambda x: jnp.ones((), x.dtype), w)
            for w in client_weights]


def init_global(client_weights: list[Pytree], how: str,
                rng: Optional[jax.Array] = None) -> Pytree:
    n = len(client_weights)
    if how == "average":
        out = client_weights[0]
        for w in client_weights[1:]:
            out = trees.tree_add(out, w)
        return trees.tree_scale(out, 1.0 / n)
    if how == "first":
        return trees.tree_map(lambda x: x, client_weights[0])
    if how == "random":
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        leaves, treedef = jax.tree_util.tree_flatten(client_weights[0])
        keys = jax.random.split(rng, len(leaves))
        new = [jax.random.normal(k, x.shape, x.dtype) *
               (jnp.std(x) + 1e-8) for k, x in zip(keys, leaves)]
        return jax.tree_util.tree_unflatten(treedef, new)
    raise ValueError(f"unknown init {how!r}")


@partial(jax.jit, static_argnames=("cfg", "convention", "levels",
                                   "backend", "mesh"))
def _maecho_jit(W0, V0, P, cfg: MAEchoConfig, convention: str,
                levels: tuple, backend: str = "oracle", mesh=None,
                masks=None):
    def outer(_, state):
        W, V = state
        flatW, treedef = jax.tree_util.tree_flatten(W)
        flatV = treedef.flatten_up_to(V)
        flatP = treedef.flatten_up_to(P)
        flatM = (list(masks) if masks is not None
                 else [None] * len(flatW))
        if cfg.qp_batched:
            # Phase 1: every leaf's (and every scanned layer's) Eq. 6
            # Gram, assembled into one (L, N, N) stack.  N — the
            # client count — is shared by construction inside one
            # aggregate call, so stack_grams degenerates to a pure
            # concat here (its padding serves the ragged case).
            grams, ctxs = [], []
            for w, v, p, lv in zip(flatW, flatV, flatP, levels):
                g, ctx = _leaf_gram(w, v, p, cfg, convention, lv,
                                    backend, mesh)
                grams.append(g)
                ctxs.append(ctx)
            Gstack, n_valid = qp_mod.stack_grams(grams)
            # Phase 2: ONE vmapped PGD solve for the whole batch —
            # with ragged participation, each leaf's client mask
            # (broadcast over its scanned layers) rides the solver's
            # validity masking instead of the prefix n_valid.
            if masks is None:
                alphas = qp_mod.solve_qp_batched(Gstack, cfg.C,
                                                 cfg.qp_iters, n_valid)
            else:
                rows = [jnp.broadcast_to(m, (math.prod(g.shape[:-2]),)
                                         + m.shape)
                        for g, m in zip(grams, flatM)]
                alphas = qp_mod.solve_qp_batched(
                    Gstack, cfg.C, cfg.qp_iters,
                    mask=jnp.concatenate(rows, 0))
            # Phase 3: … scattered back through each leaf's Eq. 7/11.
            out, ofs = [], 0
            for w, v, p, lv, ctx, g in zip(flatW, flatV, flatP, levels,
                                           ctxs, grams):
                cnt = math.prod(g.shape[:-2])
                a = alphas[ofs:ofs + cnt].reshape(
                    g.shape[:-2] + alphas.shape[-1:])
                ofs += cnt
                out.append(_leaf_apply(w, v, p, ctx, a, cfg,
                                       convention, lv, backend, mesh))
        else:
            out = [_dispatch_leaf(w, v, p, cfg, convention, lv, backend,
                                  mesh, m)
                   for w, v, p, lv, m in zip(flatW, flatV, flatP,
                                             levels, flatM)]
        if masks is not None:
            # non-participants contribute nothing (α = 0 via the QP
            # mask) and their anchors stay put — the run matches
            # aggregating the participating subset alone
            out = [(w2, jnp.where(
                        m.reshape((-1,) + (1,) * (v1.ndim - 1)),
                        v2, v1))
                   for (w2, v2), v1, m in zip(out, flatV, flatM)]
        W = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        V = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return W, V

    if cfg.tau <= 4:
        # unrolled (also gives the roofline probe loop-free HLO)
        state = (W0, V0)
        for t in range(cfg.tau):
            state = outer(t, state)
        return state
    W, V = jax.lax.fori_loop(0, cfg.tau, outer, (W0, V0))
    return W, V


def dispatch_summary(W0: Pytree, P: Pytree, levels_tree: Pytree,
                     cfg: MAEchoConfig = MAEchoConfig(),
                     convention: str = "oi", backend: str = "oracle",
                     mesh=None):
    """Per-leaf compute-path report: which backend each leaf actually
    takes under the given dispatch inputs — the visibility companion
    to ``ops.fallback_warn`` (a requested fast path silently degrading
    to the oracle is the failure mode both guard).

    ``W0`` / ``P`` are the global-weight and *stacked* (leading client
    axis) projector trees — arrays or ``jax.ShapeDtypeStruct``s both
    work, dispatch is static-shape-only.  Returns ``(per_leaf,
    counts)``: ``per_leaf`` is a list of ``(path, levels, route)``
    with route in {"oracle", "kernel", "sharded"}; ``counts`` maps
    route -> leaf count.
    """
    treedef = jax.tree_util.tree_structure(W0)
    paths = [p for p, _ in trees.tree_paths(W0)]
    flatW = jax.tree_util.tree_leaves(W0)
    flatP = treedef.flatten_up_to(P)
    flatL = jax.tree_util.tree_leaves(levels_tree)
    from repro.kernels.ops import DEFAULT_BLOCK

    per_leaf = []
    for path, w, p, lv in zip(paths, flatW, flatP, flatL):
        if lv > 0:
            route = _stacked_route(w, p, cfg, convention, backend,
                                   mesh, lv) or "oracle"
        elif _use_sharded(w, p, backend, mesh, convention,
                          cfg.mesh_axis):
            route = "sharded"
        elif _use_kernel(w, p, backend):
            route = "kernel"
        else:
            route = "oracle"
        # a "kernel"-routed leaf below one tile runs the jnp oracle
        # inside the streaming wrappers (backend="kernel" forces the
        # route, not the tiling) — report what actually executes
        if route == "kernel" and min(w.shape[-2:]) < DEFAULT_BLOCK:
            route = "oracle"
        per_leaf.append((path, lv, route))
    counts: dict = {}
    for _, _, route in per_leaf:
        counts[route] = counts.get(route, 0) + 1
    return per_leaf, counts


def _default_mesh(axis_name: str):
    """1-D mesh over every visible device — the ``backend="sharded"``
    convenience default, so ``maecho_backend="sharded"`` works without
    explicit mesh plumbing (pass a real mesh for production)."""
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()), (axis_name,))


def _normalize_client_mask(client_mask, W0, n_clients: int):
    """Per-leaf (N,) boolean masks, aligned with ``tree_flatten(W0)``.

    Accepts one (N,) mask (applies to every leaf) or a pytree matching
    the weight structure whose leaves are (N,) masks."""
    if (hasattr(client_mask, "ndim")
            or (isinstance(client_mask, (list, tuple))
                and not any(isinstance(x, (list, tuple, dict))
                            for x in client_mask))):
        m = jnp.asarray(client_mask, bool)
        mask_tree = trees.tree_map(lambda _: m, W0)
    else:
        mask_tree = jax.tree_util.tree_map(
            lambda x: jnp.asarray(x, bool), client_mask)
    treedef = jax.tree_util.tree_structure(W0)
    masks = tuple(treedef.flatten_up_to(mask_tree))
    for m in masks:
        if m.shape != (n_clients,):
            raise ValueError(
                f"client_mask leaves must be ({n_clients},) booleans, "
                f"got shape {m.shape}")
        # concrete here (outside jit): an all-False leaf would make
        # the Σα = 1 constraint unsatisfiable and silently return the
        # init point — surface the upstream participation bug instead
        if not bool(m.any()):
            raise ValueError(
                "client_mask excludes every client for some leaf — "
                "at least one participant is required")
    return masks


def maecho_aggregate(
    client_weights: list[Pytree],
    projections: Optional[list[Pytree]] = None,
    cfg: MAEchoConfig = MAEchoConfig(),
    convention: str = "oi",
    init_point: Optional[Pytree] = None,
    rng: Optional[jax.Array] = None,
    stack_levels=None,
    return_anchors: bool = False,
    backend: str = "oracle",
    mesh=None,
    client_mask=None,
):
    """Run Algorithm 1.  Returns the global model pytree.

    client_weights: list over clients of structurally identical pytrees.
    projections:    matching list of projector pytrees (see module doc);
                    ``None`` falls back to scalar full projectors.
    stack_levels:   per-leaf count of leading stacked-layer axes —
                    ``None`` (all 0, the paper's MLP/CNN layout), a
                    pytree of ints matching the weights, or a callable
                    ``path -> int`` (the LLM scan-over-layers layout).
                    Stacked leaves are first-class on every backend:
                    eligible ones fold their (flattened) layer axis
                    into the kernel grid; projector leaves must carry
                    the same leading axes.
    backend:        ``"oracle"`` | ``"kernel"`` | ``"auto"`` |
                    ``"sharded"`` — the jnp reference path, the fused
                    streaming Pallas pipeline, or its out-dim
                    mesh-sharded form (module docstring).
    mesh:           ``jax.sharding.Mesh`` carrying ``cfg.mesh_axis``
                    for ``backend="sharded"`` (default: a 1-D mesh
                    over every visible device).  Ignored otherwise.
    client_mask:    optional ragged-participation mask — one (N,)
                    boolean vector, or a pytree of them matching the
                    weight structure (per-leaf client subsets).
                    Masked-out clients get exactly α = 0, their
                    anchors are frozen, and the result matches
                    aggregating the subset alone.  At least one client
                    must be masked in per leaf.
    """
    if backend not in ("oracle", "kernel", "auto", "sharded"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "sharded" and mesh is None:
        mesh = _default_mesh(cfg.mesh_axis)
    if backend != "sharded":
        mesh = None                 # keep the jit cache key canonical
    if projections is None:
        projections = default_projections(client_weights)
    W0 = (init_point if init_point is not None
          else init_global(client_weights, cfg.init, rng))
    masks = (None if client_mask is None else
             _normalize_client_mask(client_mask, W0,
                                    len(client_weights)))
    if stack_levels is None:
        levels_tree = trees.tree_map(lambda _: 0, W0)
    elif callable(stack_levels):
        levels_tree = trees.map_with_path(
            lambda path, _: stack_levels(path), W0)
    else:
        levels_tree = stack_levels
    levels = tuple(jax.tree_util.tree_leaves(levels_tree))
    V0 = trees.tree_map(lambda *xs: jnp.stack(xs, 0), *client_weights)
    P = trees.tree_map(lambda *xs: jnp.stack(xs, 0), *projections)
    # Multi-level stacks collapse to ONE flat scan axis before dispatch
    # (pure reshape — the QP treats every scanned layer independently,
    # so per-layer semantics are unchanged): the stacked kernel grid
    # wants a single layer axis, and nested vmaps over the oracle both
    # cost an extra batch dim and trip XLA:CPU's simplifier on dense
    # projector contractions.  Outputs are reshaped back below.
    treedef = jax.tree_util.tree_structure(W0)
    multi = any(lv > 1 for lv in levels)
    if multi:
        leads = tuple(w.shape[:lv] for w, lv in
                      zip(jax.tree_util.tree_leaves(W0), levels))
        fW, fV, fP = [], [], []
        for w, v, p, lv in zip(jax.tree_util.tree_leaves(W0),
                               treedef.flatten_up_to(V0),
                               treedef.flatten_up_to(P), levels):
            if lv > 1:
                w, v, p, _ = _flatten_stack(w, v, p, lv)
            fW.append(w)
            fV.append(v)
            fP.append(p)
        W0 = jax.tree_util.tree_unflatten(treedef, fW)
        V0 = jax.tree_util.tree_unflatten(treedef, fV)
        P = jax.tree_util.tree_unflatten(treedef, fP)
    run_levels = tuple(min(lv, 1) for lv in levels) if multi else levels
    W, V = _maecho_jit(W0, V0, P, cfg, convention, run_levels, backend,
                       mesh, masks)
    if multi:
        W = jax.tree_util.tree_unflatten(treedef, [
            w.reshape(lead + w.shape[1:]) if lv > 1 else w
            for w, lead, lv in zip(jax.tree_util.tree_leaves(W),
                                   leads, levels)])
        V = jax.tree_util.tree_unflatten(treedef, [
            v.reshape(v.shape[:1] + lead + v.shape[2:]) if lv > 1 else v
            for v, lead, lv in zip(treedef.flatten_up_to(V),
                                   leads, levels)])
    return (W, V) if return_anchors else W
