"""Roofline analysis from compiled dry-run artifacts (no hardware).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = FLOPs_per_chip / peak_FLOPs          (MXU bound)
    memory     = bytes_per_chip / HBM_bw              (HBM bound)
    collective = collective_bytes_per_chip / link_bw  (ICI bound)

Sources: ``compiled.cost_analysis()`` provides flops and bytes accessed
for the *per-device* SPMD program.  Collective bytes are NOT in
cost_analysis — :func:`collective_bytes` parses the optimized HLO text
and sums result-shape bytes of every collective op, weighted by the
ring-transfer factor for its kind (all-reduce moves ~2×(n−1)/n of the
buffer per chip; all-gather/reduce-scatter ~(n−1)/n; all-to-all and
collective-permute ~1×).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment brief).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# ops and their per-chip ring-transfer byte multipliers (applied to the
# result shape; n-dependent (n-1)/n factors are folded to 1 for n >> 1)
_COLL_FACTORS = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\],{}<>:#\s]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|ragged-all-to-all)(?:-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-kind result bytes of collectives in optimized HLO."""
    out = {k: 0 for k in _COLL_FACTORS}
    count = {k: 0 for k in _COLL_FACTORS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in line:
            continue
        # result shape(s): text before '=' names the value; shapes appear
        # right after '=' — take every shape up to the op name
        lhs_rhs = line.split("=", 1)
        if len(lhs_rhs) != 2:
            continue
        rhs = lhs_rhs[1]
        op_pos = rhs.find(kind)
        shapes = _SHAPE_RE.findall(rhs[:op_pos])
        total = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind] += total
        count[kind] += 1
    return {"bytes": out, "count": count,
            "weighted_total": sum(out[k] * _COLL_FACTORS[k]
                                  for k in out)}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    bytes_per_chip: float            # HLO 'bytes accessed' (fusion-free UB)
    coll_bytes_per_chip: float
    model_flops: float            # 6·N·D (active) for the global step
    chips: int
    bytes_model_per_chip: float = 0.0  # analytic flash-aware HBM model

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        """Spec formula: HLO bytes (documented fusion-free upper bound)."""
        return self.bytes_per_chip / HBM_BW

    @property
    def t_memory_model(self) -> float:
        """Flash-aware analytic HBM traffic (used for bottleneck calls)."""
        return self.bytes_model_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        mem = self.t_memory_model or self.t_memory
        ts = {"compute": self.t_compute, "memory": mem,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (remat/dispatch waste shows
        up as a small ratio)."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on MFU implied by the dominant term."""
        t = max(self.t_compute, self.t_memory_model or self.t_memory,
                self.t_collective)
        if t == 0:
            return 0.0
        return (self.model_flops / self.chips / PEAK_FLOPS) / t

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_memory_model": self.t_memory_model,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def model_flops(cfg, shape, kind: str) -> float:
    """6·N·D for training; 2·N·D for inference (per global step)."""
    n = cfg.n_active_params()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
