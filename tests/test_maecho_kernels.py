"""Fused streaming MA-Echo aggregation pipeline: kernel-vs-oracle
parity (interpret mode) across projector kinds, padding paths and the
full-aggregate backend dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import projections as proj
from repro.core.maecho import MAEchoConfig, maecho_aggregate
from repro.kernels import ops, ref


def _layer(seed, out_d, in_d, N):
    k = jax.random.PRNGKey(seed)
    W = jax.random.normal(k, (out_d, in_d))
    V = jax.random.normal(jax.random.fold_in(k, 1), (N, out_d, in_d))
    return k, W, V


def _proj_of_kind(k, kind, N, in_d, rank=32):
    if kind == "scalar":
        return jax.random.uniform(jax.random.fold_in(k, 2), (N,))
    if kind == "diag":
        return jax.random.uniform(jax.random.fold_in(k, 2), (N, in_d))
    U = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(k, 2),
                                        (N, in_d, min(rank, in_d))))[0]
    s = jax.random.uniform(jax.random.fold_in(k, 3),
                           (N, min(rank, in_d)))
    if kind == "factored":
        return {"U": U, "s": s}
    # full: PSD low-rank-ish, per client
    return jnp.einsum("nik,nk,njk->nij", U, s, U)


KINDS = ["scalar", "diag", "full", "factored"]

# 128-multiples (direct tiling) and odd shapes (padding path)
SHAPES = [(256, 384, 3), (200, 300, 2), (128, 128, 1), (384, 140, 4)]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("out_d,in_d,N", SHAPES)
def test_gram_parity(kind, out_d, in_d, N):
    k, W, V = _layer(out_d + in_d + N, out_d, in_d, N)
    P = _proj_of_kind(k, kind, N, in_d)
    got = ops.maecho_gram_auto(W, V, P)
    want = ref.maecho_gram_ref(W, V, P)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-2, rtol=1e-4)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("norm", [False, True])
def test_v_update_parity(kind, norm):
    out_d, in_d, N = 256, 200, 3
    k, W, V = _layer(17, out_d, in_d, N)
    P = _proj_of_kind(k, kind, N, in_d)
    got = ops.maecho_v_update_auto(W, V, P, frac=0.5, norm=norm)
    want = ref.maecho_v_update_ref(W, V, P, 0.5, norm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("kind", KINDS)
def test_update_parity(kind):
    out_d, in_d, N = 200, 384, 3
    k, W, V = _layer(29, out_d, in_d, N)
    P = _proj_of_kind(k, kind, N, in_d)
    alpha = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 9),
                                             (N,)))
    got = ops.maecho_update_auto(W, V, P, alpha, eta=0.7)
    want = ref.maecho_update_ref_any(W, V, P, alpha, 0.7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_factored_rank_above_one_tile():
    """rank > 128 exercises the rank-axis padding/tiling path."""
    out_d, in_d, N, rank = 128, 256, 2, 150
    k, W, V = _layer(31, out_d, in_d, N)
    P = _proj_of_kind(k, "factored", N, in_d, rank=rank)
    got = ops.maecho_gram_auto(W, V, P)
    want = ref.maecho_gram_ref(W, V, P)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-2, rtol=1e-4)


def test_small_shapes_fall_back_to_oracle():
    """Below one tile the autos must return the oracle result exactly."""
    k, W, V = _layer(37, 6, 4, 2)
    P = _proj_of_kind(k, "full", 2, 4)
    np.testing.assert_allclose(
        np.asarray(ops.maecho_gram_auto(W, V, P)),
        np.asarray(ref.maecho_gram_ref(W, V, P)), rtol=1e-6)


def _mk_clients(seed, dims, n_clients, kind):
    clients, projs = [], []
    for i in range(n_clients):
        k = jax.random.PRNGKey(seed * 100 + i)
        c, p = [], []
        for l, (o, d) in enumerate(dims):
            kk = jax.random.fold_in(k, l)
            c.append({"W": jax.random.normal(kk, (o, d)) * 0.3,
                      "b": jax.random.normal(jax.random.fold_in(kk, 1),
                                             (o,)) * 0.1})
            if kind == "scalar":
                pw = jnp.ones(())
            elif kind == "diag":
                pw = jax.random.uniform(jax.random.fold_in(kk, 2), (d,))
            else:
                r = min(d, 16)
                U = jnp.linalg.qr(jax.random.normal(
                    jax.random.fold_in(kk, 2), (d, r)))[0]
                s = jax.random.uniform(jax.random.fold_in(kk, 3), (r,))
                pw = ({"U": U, "s": s} if kind == "factored"
                      else (U * s) @ U.T)
            p.append({"W": pw, "b": jnp.ones(())})
        clients.append(c)
        projs.append(p)
    return clients, projs


# the paper MLP (784-400-200-100-10) and CNN fc/reshaped-conv shapes
MLP_DIMS = [(400, 784), (200, 400), (100, 200), (10, 100)]
CNN_DIMS = [(64, 288), (64, 576), (256, 1024), (128, 256), (10, 128)]


@pytest.mark.slow
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("dims", [MLP_DIMS, CNN_DIMS],
                         ids=["paper-mlp", "paper-cnn"])
def test_backend_kernel_matches_oracle(kind, dims):
    clients, projs = _mk_clients(3, dims, 3, kind)
    cfg = MAEchoConfig(tau=3, eta=0.5, qp_iters=60)
    a = maecho_aggregate(clients, projs, cfg, backend="oracle")
    b = maecho_aggregate(clients, projs, cfg, backend="kernel")
    for l in range(len(dims)):
        np.testing.assert_allclose(np.asarray(a[l]["W"]),
                                   np.asarray(b[l]["W"]), atol=1e-3)
        np.testing.assert_allclose(np.asarray(a[l]["b"]),
                                   np.asarray(b[l]["b"]), atol=1e-3)


def test_backend_kernel_fori_loop_and_norm():
    """tau > 4 exercises the fori_loop outer path with kernels inside;
    norm=True exercises the fused row-norm."""
    clients, projs = _mk_clients(5, [(140, 200), (10, 140)], 3, "full")
    cfg = MAEchoConfig(tau=6, eta=0.5, qp_iters=60, norm=True, mu=2.0)
    a = maecho_aggregate(clients, projs, cfg, backend="oracle")
    b = maecho_aggregate(clients, projs, cfg, backend="kernel")
    np.testing.assert_allclose(np.asarray(a[0]["W"]),
                               np.asarray(b[0]["W"]), atol=1e-3)


@pytest.mark.parametrize("kind", KINDS)
def test_backend_kernel_io_convention(kind):
    """All projector kinds through the "io" transposition: dense is
    explicitly transposed; factored/diag rely on P's symmetry /
    elementwise action — pin that contract."""
    clients, projs = _mk_clients(7, [(150, 256)], 2, kind)
    clients_io = [[{"W": lay["W"].T, "b": lay["b"]} for lay in c]
                  for c in clients]
    cfg = MAEchoConfig(tau=3, eta=0.5, qp_iters=60)
    a = maecho_aggregate(clients_io, projs, cfg, convention="io",
                         backend="oracle")
    b = maecho_aggregate(clients_io, projs, cfg, convention="io",
                         backend="kernel")
    np.testing.assert_allclose(np.asarray(a[0]["W"]),
                               np.asarray(b[0]["W"]), atol=1e-3)


@pytest.mark.slow
def test_backend_auto_matches_oracle():
    clients, projs = _mk_clients(9, MLP_DIMS[:2], 3, "factored")
    cfg = MAEchoConfig(tau=3, eta=0.5, qp_iters=60)
    a = maecho_aggregate(clients, projs, cfg, backend="oracle")
    b = maecho_aggregate(clients, projs, cfg, backend="auto")
    np.testing.assert_allclose(np.asarray(a[0]["W"]),
                               np.asarray(b[0]["W"]), atol=1e-3)


def test_backend_rejects_unknown():
    clients, projs = _mk_clients(11, [(8, 8)], 2, "scalar")
    with pytest.raises(ValueError):
        maecho_aggregate(clients, projs, MAEchoConfig(tau=1),
                         backend="gpu")


@pytest.mark.slow
def test_factor_projection_roundtrip_through_pipeline():
    """factor_projection output plugs straight into the kernel backend
    and agrees with the dense projector it factors (exact rank)."""
    d, r = 256, 256
    X = jax.random.normal(jax.random.PRNGKey(0), (40, d))
    P = proj.projection_from_features(X, 1e-3)
    clients, _ = _mk_clients(13, [(140, d)], 2, "scalar")
    dense = [[{"W": P, "b": jnp.ones(())}] for _ in range(2)]
    fact = [[{"W": proj.factor_projection(P, r), "b": jnp.ones(())}]
            for _ in range(2)]
    cfg = MAEchoConfig(tau=2, eta=0.5, qp_iters=60)
    a = maecho_aggregate(clients, dense, cfg, backend="kernel")
    b = maecho_aggregate(clients, fact, cfg, backend="kernel")
    np.testing.assert_allclose(np.asarray(a[0]["W"]),
                               np.asarray(b[0]["W"]), atol=1e-3)
