"""Roofline machinery: HLO collective parser, three-term math,
probe extrapolation, analytic memory model."""
import pytest

from repro.configs import get_config
from repro.models.config import INPUT_SHAPES
from repro.roofline import memmodel
from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                     Roofline, collective_bytes,
                                     model_flops)
from repro.roofline.probe import probe_config, probe_units

HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[16,1024,128]{2,1,0} parameter(0)
  %ag = bf16[16,1024,2048]{2,1,0} all-gather(%p0), dimensions={2}
  %ar = f32[256,256]{1,0} all-reduce(%x), to_apply=%sum
  %rs = f32[16,64]{1,0} reduce-scatter(%y), dimensions={1}
  %a2a = bf16[8,128]{1,0} all-to-all(%z), dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %agd = bf16[2,2]{1,0} all-gather-done(%t)
}
"""


def test_collective_parser_kinds_and_bytes():
    out = collective_bytes(HLO)
    c = out["count"]
    assert c["all-gather"] == 1 and c["all-reduce"] == 1
    assert c["reduce-scatter"] == 1 and c["all-to-all"] == 1
    assert c["collective-permute"] == 1
    b = out["bytes"]
    assert b["all-gather"] == 16 * 1024 * 2048 * 2
    assert b["all-reduce"] == 256 * 256 * 4
    # weighted: AR counts 2x
    expect = (b["all-gather"] + 2 * b["all-reduce"] +
              b["reduce-scatter"] + b["all-to-all"] +
              b["collective-permute"])
    assert out["weighted_total"] == expect


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="a", shape="s", mesh="m",
                 flops_per_chip=PEAK_FLOPS,        # 1 s compute
                 bytes_per_chip=HBM_BW * 10,       # 10 s HLO-UB
                 coll_bytes_per_chip=ICI_BW * 0.5,
                 model_flops=PEAK_FLOPS * 128,
                 chips=256,
                 bytes_model_per_chip=HBM_BW * 0.2)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory_model - 0.2) < 1e-9
    assert r.bottleneck == "compute"    # model memory used, not HLO UB
    assert 0 < r.mfu_bound <= 1.0


def test_model_flops_train_decode():
    cfg = get_config("llama3_8b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"], "train")
    dec = model_flops(cfg, INPUT_SHAPES["decode_32k"], "decode")
    assert tr / dec == pytest.approx(
        3 * 256 * 4096 / 128, rel=1e-6)


def test_moe_active_params():
    cfg = get_config("qwen2_moe_a2_7b")
    assert cfg.n_active_params() < 0.35 * cfg.n_params()
    dense = get_config("llama3_8b")
    assert dense.n_active_params() == dense.n_params()


@pytest.mark.parametrize("arch", ["llama3_8b", "qwen2_moe_a2_7b",
                                  "falcon_mamba_7b", "zamba2_2_7b",
                                  "whisper_tiny"])
def test_probe_config_structure(arch):
    cfg = get_config(arch)
    for k in (1, 2):
        p = probe_config(cfg, k, seq_len=32768)
        assert p.unroll_layers and p.ssm_assoc
        assert p.microbatches == 1
        if cfg.family == "hybrid":
            assert p.n_layers == k * cfg.hybrid.attn_every
        else:
            assert p.n_layers == k
    assert probe_units(cfg) >= 4


@pytest.mark.parametrize("arch", ["llama3_8b", "llama3_405b",
                                  "qwen2_moe_a2_7b", "falcon_mamba_7b",
                                  "zamba2_2_7b", "whisper_tiny",
                                  "phi3_vision_4_2b"])
@pytest.mark.parametrize("shape_name,kind", [
    ("train_4k", "train"), ("prefill_32k", "prefill"),
    ("decode_32k", "decode"), ("long_500k", "decode")])
def test_memmodel_positive_and_sane(arch, shape_name, kind):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    b = memmodel.hbm_bytes(cfg, shape, kind, "16x16")
    assert b > 0
    # decode traffic must be at least the active params read
    if kind == "decode":
        assert b >= memmodel.active_param_bytes_local(cfg, 16, 16)
    # train traffic exceeds prefill traffic (backward + optimiser)
    if shape_name == "train_4k":
        pre = memmodel.hbm_bytes(cfg, INPUT_SHAPES["prefill_32k"],
                                 "prefill", "16x16")
        assert b > 0.1 * pre    # sanity only: different shapes


def test_memmodel_fsdp_reduces_param_traffic():
    cfg = get_config("llama3_405b")
    p_fsdp = memmodel.param_bytes_local(cfg, 16, 16)
    p_tp = memmodel.param_bytes_local(cfg.replace(fsdp=False), 16, 16)
    assert p_tp == pytest.approx(16 * p_fsdp)


def test_probe_affine_extrapolation_math():
    from repro.roofline.probe import probe_costs

    class FakeCompiled:
        def __init__(self, k):
            self.k = k

        def cost_analysis(self):
            return {"flops": 100 + 7 * self.k,
                    "bytes accessed": 10 + 3 * self.k}

        def as_text(self):
            return ""

    cfg = get_config("llama3_8b").replace(microbatches=2)

    def build(pcfg, pshape):
        return FakeCompiled(pcfg.n_layers)

    out = probe_costs(build, cfg, INPUT_SHAPES["train_4k"])
    L = cfg.n_layers
    assert out["flops"] == pytest.approx((100 + 7 * L) * 2)
    assert out["bytes"] == pytest.approx((10 + 3 * L) * 2)
