"""Decode-attention serving fast path vs the dense full-window oracle.

Property parity over the shared case space in ``tests/strategies.py``
(MHA/GQA/MQA shapes, fills past the ring-buffer wraparound point) plus
hand-picked regressions: the two Pallas grid layouts, the static
live-window crop, all-invalid masks, real ``update_kv_cache``-driven
wraparound, vector-vs-scalar cache updates, and the prefill backend
dispatch (kernel parity + forced-kernel warn-once fallback).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

import strategies as strat
from repro.kernels import ops
from repro.models import layers as L


def _oracle(q, kc, vc, valid):
    return L.decode_attention_oracle(q, kc, vc, valid)


# --------------------------------------------------------------------------
# property parity: kernel auto path vs oracle over the case space
# --------------------------------------------------------------------------
@given(strat.seeds(), strat.decode_shapes(), strat.fills())
@settings(max_examples=8, deadline=None)
def test_decode_attention_property_parity(seed, shape, fill):
    q, kc, vc, valid, _ = strat.build_decode_case(seed, shape, fill)
    got = ops.decode_attention_auto(q, kc, vc, valid)
    want = _oracle(q, kc, vc, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@given(strat.seeds(), strat.decode_shapes(), strat.fills())
@settings(max_examples=8, deadline=None)
def test_decode_attention_model_dispatcher_parity(seed, shape, fill):
    """The layers.decode_attention backend dispatcher ("kernel") agrees
    with its own oracle, including the w_live cropped variant."""
    q, kc, vc, valid, pos = strat.build_decode_case(seed, shape, fill)
    W = shape[1]
    want = _oracle(q, kc, vc, valid)
    got = L.decode_attention(q, kc, vc, valid, backend="kernel",
                             w_live=min(pos + 1, W))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# hand-picked regressions
# --------------------------------------------------------------------------
def test_wraparound_via_real_cache_updates():
    """Drive a (W=128)-slot ring past wraparound with the real
    update_kv_cache, checking kernel/oracle parity at each probe."""
    B, W, Hkv, Hq, D = 2, 128, 2, 4, 16
    k = jax.random.PRNGKey(0)
    cache = {"k": jnp.zeros((B, W, Hkv, D)),
             "v": jnp.zeros((B, W, Hkv, D))}
    valid = None
    for pos in range(W + 40):                 # wraps at pos >= W
        kk = jax.random.fold_in(k, pos)
        k_new = jax.random.normal(kk, (B, 1, Hkv, D))
        v_new = jax.random.normal(jax.random.fold_in(kk, 1),
                                  (B, 1, Hkv, D))
        cache, valid = L.update_kv_cache(cache, k_new, v_new,
                                         jnp.int32(pos))
    assert bool(jnp.all(valid))               # fully wrapped: all valid
    q = jax.random.normal(jax.random.fold_in(k, 999), (B, 1, Hq, D))
    got = ops.decode_attention_auto(q, cache["k"], cache["v"], valid)
    want = _oracle(q, cache["k"], cache["v"], valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_all_invalid_rows_are_zero_and_finite():
    """A row with no valid slot returns exact zeros from the kernel
    (documented divergence: the oracle averages v).  No NaNs either
    way — the contract the serve loop relies on for idle slots."""
    B, W, Hq, Hkv, D = 2, 256, 8, 2, 64
    k = jax.random.PRNGKey(1)
    q = jax.random.normal(k, (B, 1, Hq, D))
    kc = jax.random.normal(jax.random.fold_in(k, 1), (B, W, Hkv, D))
    vc = jax.random.normal(jax.random.fold_in(k, 2), (B, W, Hkv, D))
    valid = jnp.zeros((B, W), bool).at[1, :5].set(True)  # row 0 empty
    got = np.asarray(ops.decode_attention_auto(q, kc, vc, valid))
    assert np.all(np.isfinite(got))
    np.testing.assert_array_equal(got[0], np.zeros_like(got[0]))
    want = np.asarray(_oracle(q, kc, vc, valid))
    np.testing.assert_allclose(got[1], want[1], atol=1e-4, rtol=1e-4)


def test_gqa_grouping_matches_oracle_per_head():
    """GQA group of 4: each q head must attend through ITS kv head —
    a transposed grouping would still have matching shapes."""
    B, W, Hq, Hkv, D = 1, 128, 8, 2, 32
    k = jax.random.PRNGKey(2)
    q = jax.random.normal(k, (B, 1, Hq, D))
    kc = jax.random.normal(jax.random.fold_in(k, 1), (B, W, Hkv, D))
    vc = jax.random.normal(jax.random.fold_in(k, 2), (B, W, Hkv, D))
    valid = jnp.ones((B, W), bool)
    got = np.asarray(ops.decode_attention_auto(q, kc, vc, valid))
    # per-head dense reference: head h uses kv head h // (Hq // Hkv)
    g = Hq // Hkv
    for h in range(Hq):
        s = np.einsum("d,wd->w", np.asarray(q)[0, 0, h],
                      np.asarray(kc)[0, :, h // g]) / np.sqrt(D)
        p = np.exp(s - s.max())
        p /= p.sum()
        want_h = np.einsum("w,wd->d", p, np.asarray(vc)[0, :, h // g])
        np.testing.assert_allclose(got[0, 0, h], want_h, atol=1e-4,
                                   rtol=1e-4)


def test_fold_batch_layouts_agree():
    """The interpret-oriented whole-batch grid and the fine
    (TPU-shaped) per-(b,h) grid compute the same thing."""
    B, W, Hq, Hkv, D = 2, 256, 8, 2, 64
    q, kc, vc, valid, _ = strat.build_decode_case(7, (B, W, Hq, Hkv, D),
                                                  200)
    batched = ops.decode_attention(q, kc, vc, valid, bw=128,
                                   fold_batch=True)
    fine = ops.decode_attention(q, kc, vc, valid, bw=128,
                                fold_batch=False)
    np.testing.assert_allclose(np.asarray(batched), np.asarray(fine),
                               atol=1e-5, rtol=1e-5)


def test_w_live_crop_parity():
    """Static live-window crop (the serving fast path) is exact when
    every valid slot lies below the crop."""
    B, W, Hq, Hkv, D = 2, 512, 8, 2, 64
    fill = 130                                 # bucket -> 256 < W
    q, kc, vc, valid, pos = strat.build_decode_case(11,
                                                    (B, W, Hq, Hkv, D),
                                                    fill)
    assert ops.live_window(fill, W) == 256
    got = ops.decode_attention_auto(q, kc, vc, valid, w_live=pos + 1)
    want = _oracle(q, kc, vc, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_vector_and_scalar_cache_updates_agree():
    """Per-row (B,) positions (slot loop) write the same cache and
    mask as the scalar lockstep path when all rows share a position."""
    B, W, Hkv, D = 3, 64, 2, 16
    k = jax.random.PRNGKey(4)
    cache = {"k": jax.random.normal(k, (B, W, Hkv, D)),
             "v": jax.random.normal(jax.random.fold_in(k, 1),
                                    (B, W, Hkv, D))}
    k_new = jax.random.normal(jax.random.fold_in(k, 2), (B, 1, Hkv, D))
    v_new = jax.random.normal(jax.random.fold_in(k, 3), (B, 1, Hkv, D))
    for pos in (5, W + 7):                     # pre- and post-wrap
        c_s, m_s = L.update_kv_cache(cache, k_new, v_new,
                                     jnp.int32(pos))
        c_v, m_v = L.update_kv_cache(cache, k_new, v_new,
                                     jnp.full((B,), pos, jnp.int32))
        np.testing.assert_array_equal(np.asarray(m_s), np.asarray(m_v))
        for leaf in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(c_s[leaf]),
                                          np.asarray(c_v[leaf]))


# --------------------------------------------------------------------------
# prefill backend dispatch
# --------------------------------------------------------------------------
def test_prefill_backend_kernel_matches_oracle():
    B, S, Hq, Hkv, D = 2, 256, 8, 2, 64
    k = jax.random.PRNGKey(5)
    q = jax.random.normal(k, (B, S, Hq, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, Hkv, D))
    want = L.prefill_attention(q, kk, v, causal=True, backend="oracle")
    got = L.prefill_attention(q, kk, v, causal=True, backend="kernel")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_prefill_forced_kernel_warns_on_ineligible_shape():
    """backend="kernel" on a shape the flash kernel cannot express
    (non-causal, Sk not a block multiple) falls back with a warning —
    never silently."""
    import repro.kernels.ops as ops_mod
    B, Sq, Sk, H, D = 1, 64, 100, 2, 32
    k = jax.random.PRNGKey(6)
    q = jax.random.normal(k, (B, Sq, H, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, Sk, H, D))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, Sk, H, D))
    ops_mod._warned_fallbacks.clear()
    with pytest.warns(RuntimeWarning):
        got = L.prefill_attention(q, kk, v, causal=False,
                                  backend="kernel")
    want = L.prefill_attention(q, kk, v, causal=False, backend="oracle")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
