"""Aggregation-operator registry: the paper's method and its baselines.

Every operator maps ``list[client pytree] -> global pytree`` (plus
side-information where applicable).  These are exactly the columns of
the paper's tables: FedAvg (vanilla average), OT (neuron matching +
average), MA-Echo, MA-Echo+OT, and the Ensemble upper-ish bound
(evaluation-time logit averaging — not a parameter aggregation).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax.numpy as jnp

from repro.core import matching
from repro.core.maecho import MAEchoConfig, maecho_aggregate
from repro.utils import trees

Pytree = Any


def fedavg(client_weights: list[Pytree],
           sizes: Optional[list[float]] = None) -> Pytree:
    """Vanilla (size-weighted) parameter average [McMahan et al.]."""
    n = len(client_weights)
    w = (jnp.ones(n) / n if sizes is None
         else jnp.asarray(sizes, jnp.float32) / sum(sizes))
    out = trees.tree_scale(client_weights[0], w[0])
    for i in range(1, n):
        out = trees.tree_add(out, trees.tree_scale(client_weights[i], w[i]))
    return out


def ot_average(client_layers: list[list[dict]],
               solver: str = "hungarian") -> list[dict]:
    """Neuron matching to client 0, then average (OTFusion-style).

    Operates on MLP-layout models (list of {"W", "b"} layers).
    """
    ref = client_layers[0]
    aligned = [ref] + [matching.match_mlp(ref, c, solver)
                       for c in client_layers[1:]]
    return fedavg(aligned)


def maecho(client_weights, projections=None, cfg: MAEchoConfig = None,
           **kw) -> Pytree:
    return maecho_aggregate(client_weights, projections,
                            cfg or MAEchoConfig(), **kw)


def maecho_ot(client_layers: list[list[dict]],
              projections: list[list[dict]],
              cfg: MAEchoConfig = None, solver: str = "hungarian",
              **kw):
    """Paper §5.3: match neurons first, transform projections by
    P' = TᵀPT, then run Algorithm 1 from the average of the aligned
    models.  ``projections[i]`` is the per-layer list of
    {"W": P, "b": scalar} dicts produced by the client."""
    ref = client_layers[0]
    aligned = [ref]
    proj_aligned = [projections[0]]
    for c, pr in zip(client_layers[1:], projections[1:]):
        perms = matching.input_perms_for_mlp(ref, c, solver)
        aligned.append(matching.match_mlp(ref, c, solver))
        raw = matching.permute_projections([q["W"] for q in pr], perms)
        proj_aligned.append([{**q, "W": P} for q, P in zip(pr, raw)])
    return maecho_aggregate(aligned, proj_aligned,
                            cfg or MAEchoConfig(), **kw)


def ensemble_logits(forward: Callable, client_weights: list[Pytree], x):
    """Evaluation-time ensemble (the paper's performance goal line)."""
    logits = [jnp.asarray(forward(w, x)) for w in client_weights]
    probs = [jnp.exp(l - jnp.max(l, axis=-1, keepdims=True)) for l in logits]
    probs = [p / jnp.sum(p, axis=-1, keepdims=True) for p in probs]
    return jnp.log(sum(probs) / len(probs) + 1e-12)


AGGREGATORS = {
    "fedavg": fedavg,
    "ot": ot_average,
    "maecho": maecho,
    "maecho+ot": maecho_ot,
}
