"""Assemble EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON
records written by repro.launch.dryrun."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

ARCH_ORDER = ["llama3_8b", "qwen2_1_5b", "whisper_tiny",
              "falcon_mamba_7b", "phi3_vision_4_2b", "qwen2_moe_a2_7b",
              "llama3_405b", "zamba2_2_7b", "qwen2_0_5b", "grok1_314b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(out_dir="experiments/dryrun"):
    recs = {}
    for fn in glob.glob(os.path.join(out_dir, "*.json")):
        with open(fn) as f:
            d = json.load(f)
        recs[(d["arch"], d["shape"], d["mesh"])] = d
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.1f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def markdown_table(recs, mesh="16x16") -> str:
    lines = [
        "| arch | shape | status | t_compute | t_memory(model) | "
        "t_collective | bottleneck | useful_FLOPs | MFU bound | "
        "HLO-bytes UB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                lines.append(f"| {arch} | {shape} | MISSING | | | | "
                             "| | | |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | FAIL: "
                             f"{r['error'][:60]} | | | | | | | |")
                continue
            ro = r["roofline"]
            lines.append(
                f"| {arch} | {shape} | ok | {fmt_s(ro['t_compute'])} | "
                f"{fmt_s(ro['t_memory_model'])} | "
                f"{fmt_s(ro['t_collective'])} | {ro['bottleneck']} | "
                f"{ro['useful_flops_ratio']:.2f} | "
                f"{ro['mfu_bound']:.3f} | {fmt_s(ro['t_memory'])} |")
    return "\n".join(lines)


def memory_table(recs, mesh="16x16") -> str:
    lines = ["| arch | shape | args GB/dev | temps GB/dev | "
             "collectives (AR/AG/RS/A2A/CP) | compile s |",
             "|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if not r or r["status"] != "ok":
                continue
            m = r["memory"]
            c = r["collectives"]["count"]
            lines.append(
                f"| {arch} | {shape} | "
                f"{(m['argument_bytes'] or 0)/2**30:.2f} | "
                f"{(m['temp_bytes'] or 0)/2**30:.2f} | "
                f"{c['all-reduce']}/{c['all-gather']}/"
                f"{c['reduce-scatter']}/{c['all-to-all']}/"
                f"{c['collective-permute']} | {r['compile_s']} |")
    return "\n".join(lines)


def run(quick: bool = False):
    recs = load_records()
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    row("roofline/records", 0, f"ok={n_ok};total={len(recs)}")
    for (arch, shape, mesh), r in sorted(recs.items()):
        if r["status"] != "ok":
            row(f"roofline/{arch}/{shape}/{mesh}", 0, "FAILED")
            continue
        ro = r["roofline"]
        mfu = ro.get("mfu_bound")
        row(f"roofline/{arch}/{shape}/{mesh}",
            ro["t_compute"] * 1e6,
            f"bottleneck={ro['bottleneck']}" +
            (f";mfu_bound={mfu:.3f}" if mfu is not None else ""))


if __name__ == "__main__":
    run()
