"""2-D (out × in) mesh-sharded aggregation scaling (ISSUE 5 tentpole).

Times the sharded2d MA-Echo pipeline over factored host-device grids
(1x1 / 2x1 / 2x2 / 2x4): the two-axis Gram phase alone
(``ops.maecho_sharded2d_gram`` — per-device residual *tile* + partial
contraction + ONE psum over both axis groups) and a full
``maecho_aggregate`` with ``backend="sharded2d"``.  A "thin" row times
the fleet-spanning case the 2-D shard exists for: a leaf whose
out-dim tile count cannot divide the full device count 1-D
(``ops.sharded_ok`` rejects it) but factors over the 2-D grid.

The forced host-device count must be fixed before jax initializes, so
every grid runs in its own subprocess; each child asserts Gram parity
against the jnp oracle.  On this CPU container the "devices" share one
socket, so the curve records interpret-mode *overhead* scaling, not
the TPU speedup — the row trajectory still gates regressions in the
2-D dispatch path (two-axis padding, shard_map specs, psum placement).
Rows land in ``BENCH_sharded2d_agg.json`` via ``benchmarks.run``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import row

_CHILD = r"""
import json, os, sys
nd, nm, out_d, in_d, N, tau, thin_out = map(int, sys.argv[1:8])
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={nd * nm} "
    + os.environ.get("XLA_FLAGS", ""))
import time
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.core.maecho import MAEchoConfig, maecho_aggregate
from repro.kernels import ops

n = nd * nm
assert len(jax.devices()) >= n, (len(jax.devices()), n)
mesh = Mesh(np.asarray(jax.devices()[:n]).reshape(nd, nm),
            ("data", "model"))
k = jax.random.PRNGKey(0)
W = jax.random.normal(k, (out_d, in_d)) * 0.3
V = jax.random.normal(jax.random.fold_in(k, 1), (N, out_d, in_d)) * 0.3
U = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(k, 2),
                                    (N, in_d, 16)))[0]
s = jax.random.uniform(jax.random.fold_in(k, 3), (N, 16))
P = jnp.einsum("nik,nk,njk->nij", U, s, U)          # dense PSD


def best_of(fn, reps=3):
    out = fn()
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
    best = 1e30
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


gram = jax.jit(lambda W, V, P: ops.maecho_sharded2d_gram(
    W, V, P, mesh=mesh, axis_out="data", axis_in="model")[0])
G, gram_us = best_of(lambda: gram(W, V, P))
# parity against a float64 numpy reference: at in_d >= 1024 the fp32
# jnp oracle's own single-pass accumulation error exceeds 1e-3, while
# the kernel's blockwise fp32 scratch stays ~1e-6 — compare to truth
R64 = np.einsum("noi,nij->noj",
                np.asarray(W, np.float64)[None] - np.asarray(V,
                                                             np.float64),
                np.asarray(P, np.float64))
G64 = np.einsum("noi,moi->nm", R64, R64)
rel = float(np.max(np.abs(np.asarray(G, np.float64) - G64))
            / np.max(np.abs(G64)))
assert rel < 1e-3, f"sharded2d Gram diverged from f64 truth: rel={rel}"

clients = [{"W": V[i]} for i in range(N)]
projs = [{"W": P[i]} for i in range(N)]
cfg = MAEchoConfig(tau=tau, eta=0.5, qp_iters=60)
_, agg_us = best_of(lambda: maecho_aggregate(
    clients, projs, cfg, backend="sharded2d", mesh=mesh))

# the fleet-spanning thin leaf: 1-D-ineligible over n devices,
# 2-D-eligible over (nd, nm)
thin_us = 0.0
thin_1d_ok = True
if thin_out:
    thin_1d_ok = ops.sharded_ok(thin_out, in_d, n)
    Vt = V[:, :thin_out]
    ct = [{"W": Vt[i]} for i in range(N)]
    a, _ = best_of(lambda: maecho_aggregate(
        ct, projs, cfg, backend="oracle"))
    b, thin_us = best_of(lambda: maecho_aggregate(
        ct, projs, cfg, backend="sharded2d", mesh=mesh))
    err = float(jnp.max(jnp.abs(a["W"] - b["W"])))
    assert err < 1e-3, f"thin-leaf sharded2d parity: {err}"
print(json.dumps({"gram_us": gram_us, "agg_us": agg_us,
                  "thin_us": thin_us, "thin_1d_ok": thin_1d_ok,
                  "match": rel < 1e-3}))
"""


def _child(nd: int, nm: int, out_d: int, in_d: int, N: int, tau: int,
           thin_out: int = 0) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _CHILD, str(nd), str(nm), str(out_d),
         str(in_d), str(N), str(tau), str(thin_out)],
        env=env, capture_output=True, text=True, timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(
            f"sharded2d_agg child (grid={nd}x{nm}) failed:\n"
            f"{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def run(quick: bool = False):
    # interpret-mode sizes: the dense-P contraction is O(out·in²) per
    # client per pass on a single socket, so stay at smoke scale — the
    # trajectory gates dispatch regressions, not TPU throughput
    out_d, in_d, N, tau = ((512, 256, 3, 2) if quick
                           else (1024, 512, 3, 2))
    grids = [(1, 1), (2, 2)] if quick else [(1, 1), (2, 1), (2, 2),
                                            (2, 4)]
    thin_out = 256                       # 2 tiles: 1-D dies past d=2
    base = {}
    for nd, nm in grids:
        thin = thin_out if (nd, nm) == grids[-1] else 0
        res = _child(nd, nm, out_d, in_d, N, tau, thin)
        base.setdefault("gram", res["gram_us"])
        base.setdefault("agg", res["agg_us"])
        tag = f"out{out_d}x{in_d}_N{N}"
        row(f"sharded2d_agg/gram_d{nd}x{nm}_{tag}", res["gram_us"],
            f"vs_d1={base['gram'] / max(res['gram_us'], 1):.2f}x;"
            f"match={res['match']}")
        row(f"sharded2d_agg/agg_tau{tau}_d{nd}x{nm}_{tag}",
            res["agg_us"],
            f"vs_d1={base['agg'] / max(res['agg_us'], 1):.2f}x")
        if thin:
            row(f"sharded2d_agg/agg_thin_tau{tau}_d{nd}x{nm}_"
                f"out{thin_out}x{in_d}_N{N}", res["thin_us"],
                f"spans_{nd * nm}dev_despite_1d_ineligible="
                f"{not res['thin_1d_ok']}")


if __name__ == "__main__":
    run()
