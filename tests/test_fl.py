"""FL integration: one-shot aggregation end-to-end + multi-round loop.

The paper's headline claim — MA-Echo ≫ vanilla averaging at extreme
non-IID — validated end-to-end on the synthetic MNIST-like task
(reduced sizes to keep CI fast; the full-scale numbers live in
benchmarks/ and EXPERIMENTS.md).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.maecho import MAEchoConfig
from repro.data.partition import dirichlet_partition, label_shard_partition
from repro.data.synthetic import DatasetSpec, generate
from repro.fl import models as pm
from repro.fl.client import (LocalTrainConfig, compute_projections,
                             evaluate_classifier, train_classifier)
from repro.fl.server import one_shot_aggregate

SPEC = dataclasses.replace(pm.MLP_SPEC, hidden=(64, 32))
DATA = DatasetSpec("test", n_train=3000, n_test=800, latent=16,
                   out_dim=784//4, seed=3)


@pytest.fixture(scope="module")
def trained_clients():
    data = generate(DATA)
    spec = dataclasses.replace(SPEC, in_shape=(DATA.out_dim,))
    parts = dirichlet_partition(data["train_y"], 2, beta=0.01, seed=0)
    clients, projs = [], []
    for k, ix in enumerate(parts):
        p0 = pm.init(spec, jax.random.PRNGKey(k))
        p, _ = train_classifier(spec, p0, data["train_x"][ix],
                                data["train_y"][ix],
                                LocalTrainConfig(epochs=4))
        clients.append(p)
        projs.append(compute_projections(spec, p, data["train_x"][ix],
                                         alpha=1.0, max_samples=1024))
    return spec, data, parts, clients, projs


def test_partition_extreme_noniid():
    data = generate(DATA)
    parts = dirichlet_partition(data["train_y"], 2, beta=0.01, seed=0)
    # the vast majority of classes are concentrated on one client
    concentrated = 0
    for c in range(10):
        counts = [int((data["train_y"][ix] == c).sum()) for ix in parts]
        if max(counts) >= 0.9 * sum(counts):
            concentrated += 1
    assert concentrated >= 7


@pytest.mark.slow
def test_maecho_beats_fedavg(trained_clients):
    spec, data, parts, clients, projs = trained_clients
    acc = {}
    for method in ("fedavg", "maecho"):
        kw = dict(cfg=MAEchoConfig(tau=30, eta=0.5, mu=20.0)) \
            if method == "maecho" else {}
        g = one_shot_aggregate(spec, clients, projs, method, **kw)
        acc[method] = evaluate_classifier(spec, g, data["test_x"],
                                          data["test_y"])
    # the paper's headline: large gap at beta = 0.01
    assert acc["maecho"] > acc["fedavg"] + 0.1, acc


@pytest.mark.slow
def test_maecho_retains_both_clients(trained_clients):
    spec, data, parts, clients, projs = trained_clients
    g = one_shot_aggregate(spec, clients, projs, "maecho",
                           cfg=MAEchoConfig(tau=30, eta=0.5, mu=20.0))
    for ix in parts:
        acc = evaluate_classifier(spec, g, data["train_x"][ix][:500],
                                  data["train_y"][ix][:500])
        assert acc > 0.5, "global model forgot a client"


@pytest.mark.slow
def test_ot_matching_runs(trained_clients):
    spec, data, parts, clients, projs = trained_clients
    g = one_shot_aggregate(spec, clients, projs, "ot")
    acc = evaluate_classifier(spec, g, data["test_x"], data["test_y"])
    assert 0.0 <= acc <= 1.0


@pytest.mark.slow
def test_maecho_ot_combination(trained_clients):
    spec, data, parts, clients, projs = trained_clients
    g = one_shot_aggregate(spec, clients, projs, "maecho+ot",
                           cfg=MAEchoConfig(tau=20, eta=0.5, mu=20.0))
    acc = evaluate_classifier(spec, g, data["test_x"], data["test_y"])
    g2 = one_shot_aggregate(spec, clients, projs, "ot")
    acc2 = evaluate_classifier(spec, g2, data["test_x"], data["test_y"])
    assert acc > acc2 - 0.05    # combo at least as good as OT alone


@pytest.mark.slow
def test_cnn_aggregation_runs():
    """Conv reshape path (paper §5.2) through the full pipeline."""
    spec = dataclasses.replace(pm.CNN_SPEC, in_shape=(8, 8, 3),
                               conv_channels=(8, 8, 8),
                               fc_hidden=(16, 16))
    data = generate(DatasetSpec("cnn", n_train=600, n_test=200,
                                latent=8, out_dim=192, seed=1))
    x = data["train_x"].reshape(-1, 8, 8, 3)
    tx = data["test_x"].reshape(-1, 8, 8, 3)
    parts = dirichlet_partition(data["train_y"], 2, beta=0.1, seed=0)
    clients, projs = [], []
    for k, ix in enumerate(parts):
        p0 = pm.init(spec, jax.random.PRNGKey(k))
        p, _ = train_classifier(spec, p0, x[ix], data["train_y"][ix],
                                LocalTrainConfig(epochs=2))
        clients.append(p)
        projs.append(compute_projections(spec, p, x[ix],
                                         max_samples=256))
    g = one_shot_aggregate(spec, clients, projs, "maecho",
                           cfg=MAEchoConfig(tau=10, eta=0.5, mu=20.0, norm=True))
    acc = evaluate_classifier(spec, g, tx, data["test_y"])
    assert np.isfinite(acc)
    assert g[0]["W"].shape == clients[0][0]["W"].shape  # conv restored


@pytest.mark.slow
def test_multi_round_improves():
    from repro.fl.rounds import MultiRoundConfig, run_multi_round
    data = generate(DATA)
    spec = dataclasses.replace(SPEC, in_shape=(DATA.out_dim,))
    parts = label_shard_partition(data["train_y"], 6, 3, seed=0)
    client_data = [(data["train_x"][ix], data["train_y"][ix])
                   for ix in parts]
    cfg = MultiRoundConfig(
        n_rounds=3, n_clients=6, sample_clients=3, method="fedavg",
        local=LocalTrainConfig(epochs=1, max_steps=30))
    hist, final = run_multi_round(spec, client_data,
                                  (data["test_x"], data["test_y"]), cfg)
    assert len(hist) == 3
    assert final > 0.15     # better than chance after 3 rounds
