"""Eq. 6 dual QP: projection + solver properties (hypothesis)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.qp import (project_capped_simplex, solve_qp,
                           solve_qp_active_set)


@given(st.integers(2, 30), st.floats(0.1, 1.0), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_projection_feasible(n, c_frac, seed):
    """Projection lands in {Σα=1, 0≤α≤C} whenever it is non-empty."""
    C = max(c_frac, 1.0 / n + 1e-3)
    x = np.random.RandomState(seed).randn(n) * 3
    a = np.array(project_capped_simplex(jnp.asarray(x), C))
    assert abs(a.sum() - 1.0) < 1e-4
    assert a.min() >= -1e-6
    assert a.max() <= C + 1e-5


@given(st.integers(2, 30), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_projection_is_projection(n, seed):
    """Projecting a feasible point returns it (within tolerance)."""
    r = np.random.RandomState(seed)
    a = r.dirichlet(np.ones(n))
    out = np.array(project_capped_simplex(jnp.asarray(a), 1.0))
    np.testing.assert_allclose(out, a, atol=1e-4)


@given(st.integers(2, 12), st.integers(2, 24),
       st.sampled_from([1.0, 0.5, 0.25]), st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_pgd_matches_reference(n, d, C, seed):
    """PGD objective within tolerance of the Frank-Wolfe oracle
    (the paper's CVXOPT stand-in)."""
    if C < 1.0 / n:
        C = 1.0 / n + 1e-6
    r = np.random.RandomState(seed)
    A = r.randn(n, d)
    G = A @ A.T
    a_pgd = np.array(solve_qp(jnp.asarray(G), float(C), iters=500))
    a_ref = solve_qp_active_set(G, float(C))
    obj = lambda a: 0.5 * a @ G @ a  # noqa: E731
    assert obj(a_pgd) <= obj(a_ref) * 1.05 + 1e-6
    assert abs(a_pgd.sum() - 1) < 1e-4
    assert a_pgd.max() <= C + 1e-4


def test_capped_uniform():
    """C = 1/N forces the uniform solution (paper Prop. 1 case 2)."""
    r = np.random.RandomState(0)
    A = r.randn(6, 8)
    G = A @ A.T
    a = np.array(solve_qp(jnp.asarray(G), 1.0 / 6, iters=300))
    np.testing.assert_allclose(a, np.ones(6) / 6, atol=1e-3)


def test_uncapped_matches_unconstrained_minimum():
    """With C=1 the solution minimises ‖Σ αᵢ gᵢ‖ on the simplex."""
    g = np.array([[2.0, 0.0], [-1.0, 0.0]])   # opposite directions
    G = g @ g.T
    a = np.array(solve_qp(jnp.asarray(G), 1.0, iters=500))
    # minimiser: α = (1/3, 2/3) gives Σ α g = 0
    np.testing.assert_allclose(a, [1 / 3, 2 / 3], atol=1e-3)
