"""Loop-free probe lowerings for exact HLO cost accounting.

XLA's ``cost_analysis`` counts a ``while`` body ONCE, so the production
lowering (scan-over-layers × microbatch scan × chunked-attention loops)
under-reports FLOPs/bytes/collectives by large factors.  The probe:

  1. rebuild the config with k ∈ {1, 2} layer-units, scans unrolled,
     SSM recurrence in associative-scan form, attention single-chunk,
     microbatches=1 (batch scaled down accordingly) — loop-free HLO;
  2. cost(k) is affine in k:  cost(k) = fixed + k·per_unit, so
     per_unit = cost(2) − cost(1), fixed = 2·cost(1) − cost(2) — exact;
  3. extrapolate to the real unit count and multiply the train numbers
     back by ``microbatches``.

A layer-unit is one transformer/ssm layer (dense/moe/ssm), one
(shared-attn + attn_every·mamba2) group (hybrid), or one enc+dec layer
pair (encdec — exact because whisper has n_enc == n_dec).

The fixed part (embedding, head, loss, optimiser update) is NOT
microbatch-scaled for flops of the optimiser, a small conservative
over-count for train (documented; < 1% for every assigned config).
"""
from __future__ import annotations

import dataclasses

import jax

from repro.models.config import InputShape, ModelConfig
from repro.roofline.analysis import collective_bytes


def probe_config(cfg: ModelConfig, k: int,
                 seq_len: int = 0) -> ModelConfig:
    # attention chunk loops are unrolled; cap the number of unrolled
    # chunk bodies at ~32 (8 q-chunks × 4 kv-chunks) so the 32k/500k
    # shapes don't explode compile time — attention FLOPs are
    # chunk-size-invariant, so the extrapolation is unaffected
    kw = dict(unroll_layers=True, ssm_assoc=True, microbatches=1)
    if seq_len:
        kw["attn_chunk_q"] = max(cfg.attn_chunk_q, seq_len // 8)
        kw["attn_chunk_k"] = max(cfg.attn_chunk_k, seq_len // 4)
    if cfg.family == "hybrid":
        kw["n_layers"] = k * cfg.hybrid.attn_every
    else:
        kw["n_layers"] = k
    if cfg.encdec is not None:
        kw["encdec"] = dataclasses.replace(cfg.encdec, n_enc_layers=k)
    return cfg.replace(**kw)


def probe_units(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.hybrid.attn_every
    return cfg.n_layers


def probe_shape(cfg: ModelConfig, shape: InputShape,
                min_batch: int = 16) -> tuple:
    """Probe with the per-microbatch batch (floored at the data-axis
    size so it still shards); returns (shape, linear scale factor)."""
    if shape.kind == "train" and cfg.microbatches > 1:
        pb = max(min_batch, shape.global_batch // cfg.microbatches)
        return (dataclasses.replace(shape, global_batch=pb),
                shape.global_batch / pb)
    return shape, 1.0


def _extract(compiled) -> dict:
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll["weighted_total"]),
        "coll_count": sum(coll["count"].values()),
    }


def probe_costs(build_fn, cfg: ModelConfig, shape: InputShape,
                min_batch: int = 16) -> dict:
    """``build_fn(probe_cfg, probe_shape) -> compiled`` (arch-agnostic,
    supplied by the dry-run driver).  Returns extrapolated per-chip
    flops/bytes and total collective bytes for the REAL config."""
    ps, scale = probe_shape(cfg, shape, min_batch)
    seq = shape.seq_len if shape.kind in ("train", "prefill") else 0
    c1 = _extract(build_fn(probe_config(cfg, 1, seq), ps))
    c2 = _extract(build_fn(probe_config(cfg, 2, seq), ps))
    units = probe_units(cfg)

    out = {}
    for key in ("flops", "bytes", "coll"):
        # clamp: XLA occasionally fuses collectives differently at k=2
        # vs k=1, which would extrapolate negative
        per_unit = max(0.0, c2[key] - c1[key])
        fixed = max(0.0, 2 * c1[key] - c2[key])
        total = fixed + per_unit * units
        out[key] = total * scale
        out[f"{key}_per_unit"] = per_unit
        out[f"{key}_fixed"] = fixed
    out["coll_count_probe"] = (c1["coll_count"], c2["coll_count"])
    return out
