"""The dual QP of Eq. 6 — a one-class-SVM-shaped problem:

    min_α  ½ αᵀ G α    s.t.  Σᵢ αᵢ = 1,  0 ≤ αᵢ ≤ C

with G the Gram matrix of the per-client gradients gᵢ = 2 Pᵢ (w − vᵢ).

The paper solves this with CVXOPT on the host.  Here the solver must
*lower* inside a jitted TPU program (the aggregation step is a
first-class distributed op), so we use accelerated projected gradient
descent with an exact O(N log N + iters) projection onto the capped
simplex via bisection.  N ≤ 50 in all experiments; PGD converges to
CVXOPT-level accuracy in a few hundred cheap N×N iterations
(validated in tests/test_qp.py against an active-set reference).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def project_capped_simplex(x, C: float, iters: int = 60, mask=None):
    """Euclidean projection onto {α : Σα = 1, 0 ≤ α ≤ C}.

    Solves for τ with Σ clip(x − τ, 0, C) = 1 by bisection (monotone
    decreasing in τ); jittable, fixed iteration count.

    ``mask`` (optional, boolean, same shape as ``x``) restricts the
    simplex to the masked coordinates: unmasked entries are held at
    exactly 0 and excluded from the Σ = 1 constraint.  Used by the
    batched solver where QPs of different sizes are padded to a common
    N.  At least one entry must be masked-in.
    """
    x = x.astype(jnp.float32)
    if mask is None:
        lo = jnp.min(x) - C - 1.0
        hi = jnp.max(x)
    else:
        lo = jnp.min(jnp.where(mask, x, jnp.inf)) - C - 1.0
        hi = jnp.max(jnp.where(mask, x, -jnp.inf))

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        clipped = jnp.clip(x - mid, 0.0, C)
        if mask is not None:
            clipped = jnp.where(mask, clipped, 0.0)
        s = jnp.sum(clipped)
        # s > 1 -> tau too small -> raise lo
        lo = jnp.where(s > 1.0, mid, lo)
        hi = jnp.where(s > 1.0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    tau = 0.5 * (lo + hi)
    out = jnp.clip(x - tau, 0.0, C)
    return out if mask is None else jnp.where(mask, out, 0.0)


@partial(jax.jit, static_argnames=("iters", "row_block"))
def solve_qp(G, C: float, iters: int = 300, mask=None,
             row_block: int = 0):
    """Accelerated PGD for min ½αᵀGα on the capped simplex.

    G: (N, N) PSD Gram matrix (any positive rescaling of G gives the
    same minimiser, so callers may pass unscaled residual inner
    products).  Returns α ∈ R^N.  ``mask`` (optional (N,) boolean)
    restricts the simplex to the masked-in clients — ragged
    participation: excluded coordinates come back exactly 0, and the
    solution equals the subset QP's.  The all-valid case of
    :func:`_pgd_masked` — one iteration body to maintain.

    ``row_block`` > 0 switches to :func:`solve_qp_blocked`'s tiled
    iteration (the large-N mode): same math, the Gα product sweeps
    ``row_block`` rows of G at a time.
    """
    if mask is None:
        mask = jnp.ones((G.shape[0],), bool)
    if row_block:
        return _pgd_blocked(G, jnp.asarray(mask, bool), C, iters,
                            row_block)
    return _pgd_masked(G, jnp.asarray(mask, bool), C, iters)


def _pgd_masked(G, mask, C: float, iters: int):
    """One masked accelerated-PGD solve (the body of :func:`solve_qp`
    and the vmap body of :func:`solve_qp_batched`).

    G: (Nmax, Nmax) with arbitrary values in padded rows/columns (they
    are zeroed here); mask: (Nmax,) boolean validity.  Returns α with
    exact zeros on padded coordinates.
    """
    pair = mask[:, None] & mask[None, :]
    Gm = jnp.where(pair, G.astype(jnp.float32), 0.0)
    # Lipschitz bound: masked row-sum norm (padded rows sum to 0)
    L = jnp.maximum(jnp.max(jnp.sum(jnp.abs(Gm), axis=1)), 1e-12)
    step = 1.0 / L
    n = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    a0 = project_capped_simplex(
        jnp.where(mask, 1.0 / n, 0.0), C, mask=mask)

    def body(_, state):
        a, y, t = state
        a_new = project_capped_simplex(y - step * (Gm @ y), C, mask=mask)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_new = a_new + ((t - 1.0) / t_new) * (a_new - a)
        return a_new, y_new, t_new

    a, _, _ = jax.lax.fori_loop(0, iters, body, (a0, a0, jnp.float32(1.0)))
    return a


def _pgd_blocked(G, mask, C: float, iters: int, row_block: int):
    """The blocked twin of :func:`_pgd_masked` for large N: identical
    FISTA iteration (same step size, same projection bisection, same
    init), but the Gα product and the Lipschitz row-sum bound sweep
    ``row_block`` rows of G at a time instead of touching the whole
    (N, N) matrix per op — no (N, N) masked copy is ever made (masking
    uses (Gm α)ᵢ = maskᵢ·(G (mask·α))ᵢ), so the solver's working set
    beyond G itself is O(N + row_block·N).  The last ragged block
    re-reads (and re-writes identical values for) a few overlapping
    rows rather than branching on a partial width.
    """
    G = G.astype(jnp.float32)
    mask_f = mask.astype(jnp.float32)
    N = G.shape[0]
    rb = max(1, min(int(row_block), N))
    nb = -(-N // rb)

    def row_start(i):
        return jnp.minimum(i * rb, N - rb)

    def matvec(y):
        ym = y * mask_f

        def blk(i, out):
            st = row_start(i)
            rows = jax.lax.dynamic_slice_in_dim(G, st, rb, 0)
            return jax.lax.dynamic_update_slice_in_dim(
                out, rows @ ym, st, 0)

        return jax.lax.fori_loop(
            0, nb, blk, jnp.zeros((N,), jnp.float32)) * mask_f

    def lmax(i, cur):
        st = row_start(i)
        rows = jax.lax.dynamic_slice_in_dim(G, st, rb, 0)
        rsum = (jnp.abs(rows) @ mask_f) \
            * jax.lax.dynamic_slice_in_dim(mask_f, st, rb, 0)
        return jnp.maximum(cur, jnp.max(rsum))

    L = jnp.maximum(jax.lax.fori_loop(0, nb, lmax, jnp.float32(0.0)),
                    1e-12)
    step = 1.0 / L
    n = jnp.maximum(jnp.sum(mask_f), 1.0)
    a0 = project_capped_simplex(
        jnp.where(mask, 1.0 / n, 0.0), C, mask=mask)

    def body(_, state):
        a, y, t = state
        a_new = project_capped_simplex(y - step * matvec(y), C,
                                       mask=mask)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_new = a_new + ((t - 1.0) / t_new) * (a_new - a)
        return a_new, y_new, t_new

    a, _, _ = jax.lax.fori_loop(0, iters, body,
                                (a0, a0, jnp.float32(1.0)))
    return a


@partial(jax.jit, static_argnames=("iters", "row_block"))
def solve_qp_blocked(G, C: float, iters: int = 300, mask=None,
                     row_block: int = 64):
    """Blocked capped-simplex PGD — :func:`solve_qp` with the tiled
    Gα sweep forced on.  The large-N entry point (N in the thousands):
    per-iteration working memory beyond G is O(N + row_block·N).
    Parity with :func:`solve_qp` at small N is float32-exact up to
    matmul tiling (tests pin it to 1e-6)."""
    if mask is None:
        mask = jnp.ones((G.shape[0],), bool)
    return _pgd_blocked(G, jnp.asarray(mask, bool), C, iters,
                        row_block)


def solve_qp_batched(G, C: float, iters: int = 300, n_valid=None,
                     mask=None, row_block: int = 0):
    """One vmapped accelerated-PGD solve for a whole stack of QPs.

    G: (L, Nmax, Nmax) stacked Gram matrices — one per leaf (and per
    scanned layer) of a MA-Echo outer iteration, padded to the max N
    across leaves.  ``n_valid`` is an (L,) int vector giving each QP's
    true size (``None`` means all full: the common case inside
    ``maecho_aggregate``, where every leaf sees the same client count).
    Rows/columns at index ≥ n_valid[l] are padding; the corresponding
    α entries come back as exact zeros.

    ``mask`` (optional (L, Nmax) boolean) overrides ``n_valid`` with
    arbitrary — not necessarily prefix — per-QP validity: the ragged
    client-participation case, where each leaf's active client subset
    is any subset of the stacked cohort.  Masked-out α entries come
    back exactly 0 and the solve matches the subset QP.

    Identical iteration rule to :func:`solve_qp` (same step size, same
    projection bisection), so a full-size batch matches L sequential
    solves to float32 round-off.  Returns (L, Nmax).

    ``row_block`` > 0 vmaps the blocked iteration of
    :func:`solve_qp_blocked` instead — the large-N executor path,
    same FISTA rule with the Gα products tiled over row blocks.
    """
    L, Nmax = G.shape[0], G.shape[-1]
    if mask is not None:
        mask = jnp.asarray(mask, bool)
    elif n_valid is None:
        mask = jnp.ones((L, Nmax), bool)
    else:
        n_valid = jnp.asarray(n_valid, jnp.int32)
        mask = jnp.arange(Nmax)[None, :] < n_valid[:, None]
    if row_block:
        return jax.vmap(_pgd_blocked,
                        in_axes=(0, 0, None, None, None))(
            G, mask, C, iters, row_block)
    return jax.vmap(_pgd_masked, in_axes=(0, 0, None, None))(
        G, mask, C, iters)


def stack_grams(grams):
    """Pad a list of ragged (..., N_l, N_l) Gram stacks to a single
    (ΣL_l, Nmax, Nmax) tensor plus its (ΣL_l,) validity vector.

    Each entry may carry leading batch axes (stacked-layer leaves);
    they are flattened into the QP axis.  This is the assembly step of
    the batched outer iteration: all leaves' QPs ride one
    :func:`solve_qp_batched` call.
    """
    flat = [g.reshape((-1,) + g.shape[-2:]) for g in grams]
    n_max = max(g.shape[-1] for g in flat)
    padded, valid = [], []
    for g in flat:
        n = g.shape[-1]
        if n < n_max:
            g = jnp.pad(g, ((0, 0), (0, n_max - n), (0, n_max - n)))
        padded.append(g)
        valid.extend([n] * g.shape[0])
    return jnp.concatenate(padded, 0), jnp.asarray(valid, jnp.int32)


def solve_qp_active_set(G, C: float, tol: float = 1e-10,
                        max_iter: int = 1000):
    """Reference dense solver (numpy, Frank-Wolfe with away steps).

    Used in tests as the CVXOPT stand-in oracle for :func:`solve_qp`.
    """
    import numpy as np

    G = np.asarray(G, dtype=np.float64)
    N = G.shape[0]
    a = np.full(N, 1.0 / N)
    a = np.clip(a, 0, C)
    a /= a.sum()
    for _ in range(max_iter):
        g = G @ a
        # FW vertex of the capped simplex: put as much mass as possible
        # on the smallest-gradient coordinates
        order = np.argsort(g)
        s = np.zeros(N)
        rem = 1.0
        for i in order:
            s[i] = min(C, rem)
            rem -= s[i]
            if rem <= 0:
                break
        d = s - a
        gap = -g @ d
        if gap < tol:
            break
        # exact line search on quadratic
        dGd = d @ G @ d
        t = 1.0 if dGd <= 0 else min(1.0, gap / dGd)
        a = a + t * d
    return a
