"""End-to-end training driver (deliverable b).

Runs real optimisation steps — on this CPU container with a reduced
config ("--smoke", default) or on a real mesh with the full config.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --no-smoke \
      --mesh 16x16        # on hardware
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.serialize import save
from repro.configs import get_config, get_smoke_config
from repro.data.synthetic import lm_token_batches
from repro.models.config import InputShape
from repro.models.zoo import get_model
from repro.optim import adamw, cosine_schedule, sgd


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgd"])
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    cfg = cfg.replace(microbatches=1)
    model = get_model(cfg)
    print(f"arch={cfg.name} params≈{cfg.n_params()/1e6:.1f}M "
          f"(smoke={args.smoke})")

    params = model.init_params(jax.random.PRNGKey(args.seed))
    if args.optimizer == "adamw":
        opt = adamw(cosine_schedule(args.lr, warmup=20, total=args.steps))
    else:
        opt = sgd(args.lr, momentum=0.5)
    opt_state = opt.init(params)
    train_step = jax.jit(model.make_train_step(opt))

    shape = InputShape("cli", args.seq, args.batch, "train")
    gen = lm_token_batches(cfg.vocab, args.batch, args.seq,
                           args.steps, seed=args.seed)
    t0 = time.time()
    losses = []
    for step, batch in enumerate(gen):
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.vlm.n_patches, cfg.vlm.d_vision),
                cfg.cdtype)
        if cfg.family == "encdec":
            batch = {
                "audio_embeds": jnp.zeros(
                    (args.batch, cfg.encdec.enc_seq, cfg.d_model),
                    cfg.cdtype),
                "tokens": batch["tokens"][:, :cfg.encdec.dec_seq],
                "labels": batch["labels"][:, :cfg.encdec.dec_seq],
            }
        params, opt_state, loss = train_step(params, opt_state, batch,
                                             jnp.int32(step))
        losses.append(float(loss))
        if step % args.log_every == 0:
            dt = time.time() - t0
            print(f"step {step:5d} loss {float(loss):7.4f} "
                  f"({dt / (step + 1):5.2f}s/step)")
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}) — "
          f"{'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'}")
    if args.checkpoint:
        save(args.checkpoint, {"params": params, "losses": losses})
        print(f"saved {args.checkpoint}")


if __name__ == "__main__":
    main()
