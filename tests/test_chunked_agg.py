"""Client-chunked aggregation (ISSUE 10 tentpole): end-to-end
chunked-vs-unchunked parity across the property space, the ragged
``client_mask`` × chunk-boundary edge cases, the blocked capped-simplex
QP, the plan-layer ``client_chunk`` contracts (clamping, memoization,
the sharded2d degrade), and the two-tier hierarchical mode — mirroring
``tests/test_stacked_agg.py``'s contract style.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

import strategies as strat
from repro.core import qp
from repro.core.maecho import (MAEchoConfig, dispatch_summary,
                               maecho_aggregate)
from repro.core.plan import compile_plan
from repro.fl.rounds import maecho_aggregate_hierarchical
from repro.kernels import ops, ref

CFG = MAEchoConfig(tau=2, eta=0.5, qp_iters=60)


def _chunked(cfg, chunk):
    return dataclasses.replace(cfg, client_chunk=chunk)


def _assert_tree_close(a, b, atol=2e-3):
    for (pa, la), (pb, lb) in zip(
            jax.tree_util.tree_leaves_with_path(a),
            jax.tree_util.tree_leaves_with_path(b)):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb), atol=atol, rtol=1e-3,
            err_msg=f"leaf {pa}")


# --------------------------------------------------------------------------
# end-to-end property parity: chunked == unchunked per backend
# --------------------------------------------------------------------------
@given(strat.seeds(), strat.n_clients(), strat.kinds(),
       strat.conventions(), strat.shapes(), strat.masked())
@settings(max_examples=6, deadline=None)
def test_chunked_aggregate_parity(seed, n, kind, convention, shape,
                                  use_mask):
    clients, projs, levels, mask = strat.build_case(
        seed, n, kind, convention, (), shape, use_mask)
    want = maecho_aggregate(clients, projs, CFG,
                            convention=convention,
                            stack_levels=levels, client_mask=mask)
    got = maecho_aggregate(clients, projs, _chunked(CFG, 2),
                           convention=convention,
                           stack_levels=levels, client_mask=mask)
    _assert_tree_close(got, want)


@given(strat.seeds(), strat.n_clients(), strat.kinds(),
       strat.leads(), strat.masked())
@settings(max_examples=5, deadline=None)
def test_chunked_aggregate_parity_stacked(seed, n, kind, lead,
                                          use_mask):
    clients, projs, levels, mask = strat.build_case(
        seed, n, kind, "oi", lead, (128, 128), use_mask)
    want = maecho_aggregate(clients, projs, CFG,
                            stack_levels=levels, client_mask=mask)
    got = maecho_aggregate(clients, projs, _chunked(CFG, 2),
                           stack_levels=levels, client_mask=mask)
    _assert_tree_close(got, want)


@pytest.mark.parametrize("backend", ["kernel", "auto"])
def test_chunked_parity_fast_backends(backend):
    """Chunking composes with the kernel/auto routes — same result as
    the unchunked oracle path."""
    clients, projs, levels, mask = strat.build_case(
        7, 4, "factored", "oi", (), (256, 140), True)
    want = maecho_aggregate(clients, projs, CFG, stack_levels=levels,
                            client_mask=mask)
    got = maecho_aggregate(clients, projs, _chunked(CFG, 2),
                           stack_levels=levels, backend=backend,
                           client_mask=mask)
    _assert_tree_close(got, want)


# --------------------------------------------------------------------------
# client_mask × chunk-boundary edge cases
# --------------------------------------------------------------------------
def _mask_case(n, seed=11, shape=(48, 64)):
    return strat.build_case(seed, n, "full", "oi", (), shape, False)


@pytest.mark.parametrize("mask,n,chunk", [
    # chunk 0 keeps a single participant
    ([True, False, False, True, True, True], 6, 2),
    # chunk 1 is fully absent (both its clients masked out)
    ([True, True, False, False, True, True], 6, 2),
    # chunk boundary does not divide N (last chunk is ragged) AND the
    # ragged tail chunk is fully absent
    ([True, True, True, True, False], 5, 2),
    # everything at once: ragged tail, dead middle chunk, singleton
    ([True, False, False, False, True, True, False], 7, 3),
])
def test_chunked_mask_edges(mask, n, chunk):
    """Dead chunks (α=0 via the mask), singleton chunks and ragged
    tails all reproduce the unchunked masked aggregate — including the
    anchors: a masked client's anchor must stay frozen through the
    chunked Eq. 11 sweep exactly as through the unchunked one."""
    clients, projs, levels, _ = _mask_case(n)
    mask = np.asarray(mask)
    want_w, want_v = maecho_aggregate(
        clients, projs, CFG, stack_levels=levels, client_mask=mask,
        return_anchors=True)
    got_w, got_v = maecho_aggregate(
        clients, projs, _chunked(CFG, chunk), stack_levels=levels,
        client_mask=mask, return_anchors=True)
    _assert_tree_close(got_w, want_w)
    _assert_tree_close(got_v, want_v)


def test_chunk_larger_than_n_is_identity():
    """chunk ≥ N clamps to N — one chunk, same numbers, and the plan
    records the clamped value."""
    clients, projs, levels, _ = _mask_case(4)
    want = maecho_aggregate(clients, projs, CFG, stack_levels=levels)
    got = maecho_aggregate(clients, projs, _chunked(CFG, 64),
                           stack_levels=levels)
    _assert_tree_close(got, want)


# --------------------------------------------------------------------------
# ops-level: the fori_loop sweep really bounds residual liveness
# --------------------------------------------------------------------------
def test_chunked_gram_peak_memory_bounded():
    """The compiled chunked gram's temp footprint stays well under the
    full-residual footprint — the regression mode where a static
    unroll lets XLA CSE every chunk residual back to O(N) liveness."""
    N, out_d, in_d, chunk = 64, 128, 128, 8
    k = jax.random.PRNGKey(0)
    W = jax.random.normal(k, (out_d, in_d)) * 0.3
    V = jax.random.normal(jax.random.fold_in(k, 1),
                          (N, out_d, in_d)) * 0.3
    P = jax.random.uniform(jax.random.fold_in(k, 2), (N, in_d))

    def chunked(W, V, P):
        return ops.maecho_streaming_gram_chunked(W, V, P,
                                                 chunk=chunk)[0]

    mem = jax.jit(chunked).lower(W, V, P).compile().memory_analysis()
    full_resid = N * out_d * in_d * 4
    # 2 chunk residuals + the Gram carry + slack; full-N liveness
    # would be ≥ full_resid
    assert int(mem.temp_size_in_bytes) < full_resid // 2, (
        f"chunked gram temp {int(mem.temp_size_in_bytes)}B is not "
        f"O(chunk) (full residual = {full_resid}B)")
    np.testing.assert_allclose(
        np.asarray(chunked(W, V, P)),
        np.asarray(ref.maecho_gram_ref(W, V, P)),
        atol=1e-2, rtol=1e-4)


# --------------------------------------------------------------------------
# blocked capped-simplex QP
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,rb", [(5, 2), (8, 3), (16, 16), (12, 64),
                                  (17, 7)])
@pytest.mark.parametrize("use_mask", [False, True])
def test_solve_qp_blocked_parity(n, rb, use_mask):
    k = jax.random.PRNGKey(n * 31 + rb)
    X = jax.random.normal(k, (n, n + 3)) * 0.5
    G = X @ X.T + 0.1 * jnp.eye(n)
    mask = None
    if use_mask:
        mask = jnp.asarray(
            np.arange(n) % 3 != 1, jnp.float32)
    want = qp.solve_qp(G, 0.6, iters=200, mask=mask)
    got = qp.solve_qp_blocked(G, 0.6, iters=200, mask=mask,
                              row_block=rb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)
    # solve_qp's row_block kwarg routes to the same blocked PGD
    got2 = qp.solve_qp(G, 0.6, iters=200, mask=mask, row_block=rb)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want),
                               atol=1e-5)


def test_solve_qp_batched_row_block_parity():
    k = jax.random.PRNGKey(3)
    X = jax.random.normal(k, (4, 9, 12)) * 0.5
    G = jnp.einsum("bnd,bmd->bnm", X, X) + 0.1 * jnp.eye(9)
    want = qp.solve_qp_batched(G, 0.6, iters=150)
    got = qp.solve_qp_batched(G, 0.6, iters=150, row_block=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


# --------------------------------------------------------------------------
# plan layer: client_chunk field, clamping, memoization, sharded2d
# --------------------------------------------------------------------------
def _plan_args(n=4, chunk=0):
    W0 = {"W": jnp.zeros((256, 128)), "b": jnp.zeros((256,))}
    Pp = {"W": jnp.zeros((n, 128, 128)), "b": jnp.zeros((n,))}
    levels = {"W": 0, "b": 0}
    cfg = MAEchoConfig(tau=2, client_chunk=chunk)
    return W0, Pp, levels, cfg


def test_plan_records_clamped_chunk():
    W0, Pp, levels, cfg = _plan_args(n=4, chunk=64)
    plan = compile_plan(W0, Pp, levels, cfg, "oi", "kernel", None)
    by_path = {lp.path: lp for lp in plan.leaves}
    assert by_path["W"].client_chunk == 4        # clamped to N
    assert by_path["b"].client_chunk == 0        # bias never chunks


def test_plan_memoizes_on_chunk():
    W0, Pp, levels, cfg = _plan_args(n=4, chunk=2)
    p1 = compile_plan(W0, Pp, levels, cfg, "oi", "kernel", None)
    p2 = compile_plan(W0, Pp, levels, cfg, "oi", "kernel", None)
    assert p1 is p2
    cfg0 = dataclasses.replace(cfg, client_chunk=0)
    p3 = compile_plan(W0, Pp, levels, cfg0, "oi", "kernel", None)
    assert p3 is not p1
    assert all(lp.client_chunk == 0 for lp in p3.leaves)


def test_sharded2d_with_chunk_degrades_with_warning():
    """backend='sharded2d' + client_chunk has no composed kernel: the
    plan degrades the leaf to the 1-D out-dim shard and says so."""
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    # n=6/chunk=3 keeps the (deduped) warning message unique to this
    # test across the session
    W0, Pp, levels, cfg = _plan_args(n=6, chunk=3)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        plan = compile_plan(W0, Pp, levels, cfg, "oi", "sharded2d",
                            mesh)
    assert any("does not compose with client chunking" in str(w.message)
               for w in rec)
    assert all(lp.route != "sharded2d" for lp in plan.leaves)
    by_path = {lp.path: lp for lp in plan.leaves}
    assert by_path["W"].client_chunk == 3        # chunk survives


def test_dispatch_summary_counts_chunked():
    W0, Pp, levels, cfg = _plan_args(n=4, chunk=2)
    _, counts = dispatch_summary(W0, Pp, levels, cfg, "oi", "kernel",
                                 None)
    assert counts.get("chunked") == 1
    _, counts0 = dispatch_summary(
        W0, Pp, levels, dataclasses.replace(cfg, client_chunk=0),
        "oi", "kernel", None)
    assert "chunked" not in counts0


# --------------------------------------------------------------------------
# hierarchical two-tier aggregation
# --------------------------------------------------------------------------
def test_hierarchical_single_group_is_flat():
    """group_size ≥ N collapses to one tier-1 group whose result is
    returned unchanged — exact parity with the flat aggregate."""
    clients, projs, levels, _ = _mask_case(5)
    flat = maecho_aggregate(clients, projs, CFG, stack_levels=levels)
    hier = maecho_aggregate_hierarchical(
        clients, projs, CFG, group_size=8, stack_levels=levels)
    for key in flat:
        np.testing.assert_array_equal(np.asarray(flat[key]),
                                      np.asarray(hier[key]))


def test_hierarchical_dead_group_equals_reduced_flat():
    """A group whose clients are all masked out contributes nothing;
    with only one surviving group the result equals the flat aggregate
    of just that group's clients."""
    clients, projs, levels, _ = _mask_case(4)
    mask = np.asarray([True, True, False, False])
    hier = maecho_aggregate_hierarchical(
        clients, projs, CFG, group_size=2, stack_levels=levels,
        client_mask=mask)
    flat = maecho_aggregate(clients[:2], projs[:2], CFG,
                            stack_levels=levels)
    for key in flat:
        np.testing.assert_array_equal(np.asarray(flat[key]),
                                      np.asarray(hier[key]))


def test_hierarchical_two_tier_runs_and_composes_with_chunking():
    clients, projs, levels, _ = _mask_case(6)
    mask = np.asarray([True, True, True, False, True, True])
    out = maecho_aggregate_hierarchical(
        clients, projs, _chunked(CFG, 2), group_size=2,
        stack_levels=levels, client_mask=mask,
        tier2_cfg=dataclasses.replace(CFG, tau=1))
    for key, leaf in out.items():
        assert np.all(np.isfinite(np.asarray(leaf))), key
        assert leaf.shape == clients[0][key].shape


def test_hierarchical_rejects_bad_inputs():
    clients, projs, levels, _ = _mask_case(4)
    with pytest.raises(ValueError, match="group_size"):
        maecho_aggregate_hierarchical(clients, projs, CFG,
                                      group_size=0)
    with pytest.raises(ValueError, match="client_mask"):
        maecho_aggregate_hierarchical(
            clients, projs, CFG, group_size=2,
            client_mask=np.asarray([True, False]))
    with pytest.raises(ValueError, match="excludes every client"):
        maecho_aggregate_hierarchical(
            clients, projs, CFG, group_size=2,
            client_mask=np.zeros(4, bool))
