"""Neuron-matching baselines (paper §4 Eq. 1) and the MA-Echo+OT combo.

Cross-model neuron alignment: per layer, find a permutation T minimising
‖W_ref − T·W_i‖²_F (rows = output neurons), propagate the permutation
into the next layer's input dimension, and average the re-aligned
models.  This covers the behaviour of OTFusion [19] / FedMA-style [20]
hard matching used as the paper's strongest parameter-space baseline.

Combination with MA-Echo (paper §5.3): after matching, projections
transform as P' = T*ᵀ P T* — implemented in :func:`permute_projections`.

The assignment problem is solved with scipy's Hungarian solver on host
(matching is a pre-processing step, not part of the lowered program);
a Sinkhorn-based soft matcher is provided for fully-jitted pipelines.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np


def _pairwise_sq_dists(A, B):
    """(n, d), (n, d) -> (n, n) squared euclidean distances."""
    a2 = np.sum(A * A, axis=1)[:, None]
    b2 = np.sum(B * B, axis=1)[None, :]
    return a2 + b2 - 2.0 * (A @ B.T)


def match_layer(W_ref, W_i) -> np.ndarray:
    """Permutation π with W_i[π] ≈ W_ref (rows = output neurons).

    Returns the row-index array: aligned = W_i[π].
    """
    from scipy.optimize import linear_sum_assignment

    cost = _pairwise_sq_dists(np.asarray(W_ref, np.float64),
                              np.asarray(W_i, np.float64))
    rows, cols = linear_sum_assignment(cost)
    perm = np.empty(len(rows), dtype=np.int64)
    perm[rows] = cols
    return perm


def sinkhorn_match_layer(W_ref, W_i, reg: float = 0.05,
                         iters: int = 200) -> np.ndarray:
    """Entropic OT + hardening — jnp-only alternative to Hungarian."""
    cost = _pairwise_sq_dists(np.asarray(W_ref, np.float64),
                              np.asarray(W_i, np.float64))
    cost = cost / (cost.max() + 1e-12)
    K = np.exp(-cost / reg)
    u = np.ones(cost.shape[0])
    for _ in range(iters):
        v = 1.0 / (K.T @ u + 1e-30)
        u = 1.0 / (K @ v + 1e-30)
    T = u[:, None] * K * v[None, :]
    # harden greedily
    perm = np.full(cost.shape[0], -1, dtype=np.int64)
    taken = np.zeros(cost.shape[0], dtype=bool)
    order = np.argsort(-T.max(axis=1))
    for r in order:
        cands = np.argsort(-T[r])
        for c in cands:
            if not taken[c]:
                perm[r] = c
                taken[c] = True
                break
    return perm


def match_mlp(ref_layers: list[dict], layers: list[dict],
              solver: str = "hungarian") -> list[dict]:
    """Align one MLP-style client (list of {"W": (out,in), "b"}) to a
    reference, permuting each hidden layer's outputs and the next
    layer's inputs.  The final (classifier) layer is not permuted."""
    fn = match_layer if solver == "hungarian" else sinkhorn_match_layer
    aligned = [dict(lay) for lay in layers]
    in_perm: Optional[np.ndarray] = None
    for idx, lay in enumerate(aligned):
        W = np.asarray(lay["W"])
        if in_perm is not None:
            W = W[:, in_perm]
        if idx < len(aligned) - 1:
            ref = np.asarray(ref_layers[idx]["W"])
            perm = fn(ref, W)
            W = W[perm]
            b = np.asarray(lay["b"])[perm]
            in_perm = perm
        else:
            b = np.asarray(lay["b"])
            in_perm = None
        aligned[idx] = {**lay, "W": jnp.asarray(W), "b": jnp.asarray(b)}
    return aligned


def permute_projections(proj_layers: list, perms: list) -> list:
    """P' = T*ᵀ P T* (paper §5.3): reindex each projector by the input
    permutation applied to its layer."""
    out = []
    for P, perm in zip(proj_layers, perms):
        if perm is None or P.ndim == 0:
            out.append(P)
        elif P.ndim == 1:
            out.append(P[perm])
        else:
            out.append(P[np.ix_(perm, perm)])
    return out


def input_perms_for_mlp(ref_layers: list[dict], layers: list[dict],
                        solver: str = "hungarian") -> list:
    """The input-side permutation experienced by each layer after
    output-matching the previous one (first layer: identity/None)."""
    fn = match_layer if solver == "hungarian" else sinkhorn_match_layer
    perms: list = [None]
    in_perm: Optional[np.ndarray] = None
    for idx, lay in enumerate(layers[:-1]):
        W = np.asarray(lay["W"])
        if in_perm is not None:
            W = W[:, in_perm]
        perm = fn(np.asarray(ref_layers[idx]["W"]), W)
        perms.append(perm)
        in_perm = perm
    return perms
