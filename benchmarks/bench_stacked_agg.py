"""Stacked-leaf aggregation benchmark (ISSUE 4 tentpole).

Times one-shot MA-Echo aggregation of a scan-over-layers leaf
(L, out, in) — the LLM transformer-stack layout — at L ∈ {2, 4, 8, 16}
on the jnp oracle vs the stacked kernel pipeline (the layer axis
folded into the Pallas grid, ``backend="kernel"``), and records the
hardware-target win alongside.

Two numbers per row, with very different meanings:

- ``us_per_call`` is interpret-mode wall clock on this CPU container.
  Like ``bench_sharded_agg``, kernel-row timing here is *simulation
  overhead tracking*, not a speedup claim — the Pallas interpreter
  executes the grid sequentially with per-step dynamic-slice copies,
  so the jnp oracle (straight XLA:CPU BLAS) is faster in wall clock.
  The rows still gate regressions in the stacked dispatch path
  (padding, flattening, grid construction, QP plumbing); kernel rows
  run at ``kernel_block=512`` so the interpreter's per-step overhead
  does not drown the trajectory.
- the ``derived`` field carries the TPU-target claim, exactly
  computed from tensor shapes (the same reasoning as
  ``roofline/memmodel.py``, which exists because CPU-side byte counts
  are meaningless for the TPU target): per outer iteration the oracle
  path materializes the (N, L, out, in) fp32 residual in HBM twice
  (Eq. 6/7 and the Eq. 11 reprojection), while the stacked kernel
  pipeline's HBM-resident working set is the (N, L, out, k)
  compressed residual (factored projectors) or nothing at all
  (dense/diag — residual tiles live and die in VMEM).
  ``resid_x = in / k`` (16.0 at in=512, k=32) is the recorded
  ≥2x-over-oracle acceptance metric at every L, including L ≥ 8.
  ``kernel_programs`` pins the launch contract: exactly 3 distinct
  Pallas kernels in the whole program (gram, Eq. 7, Eq. 11 — each
  launched once per leaf per outer iteration with the layer axis on
  its grid) regardless of L — the pre-PR dispatch compiled 0 kernels
  and ran a vmapped oracle instead.

Parity between the two paths is asserted (<1e-3) before any row is
emitted.  Rows land in ``BENCH_stacked_agg.json`` via
``benchmarks.run``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core.maecho import MAEchoConfig, maecho_aggregate

N_CLIENTS = 4
OUT_D, IN_D, RANK = 512, 512, 32
KERNEL_BLOCK = 512
F32 = 4


def _make_stacked(L: int, kind: str):
    """N clients of one scan-over-layers leaf {(L, out, in)} plus
    per-layer projectors of the given kind."""
    clients, projs = [], []
    for i in range(N_CLIENTS):
        k = jax.random.PRNGKey(7 * i + 1)
        W = jax.random.normal(k, (L, OUT_D, IN_D)) * 0.3
        if kind == "diag":
            pw = jax.random.uniform(jax.random.fold_in(k, 2),
                                    (L, IN_D))
        else:
            U = jnp.linalg.qr(jax.random.normal(
                jax.random.fold_in(k, 2), (L, IN_D, RANK)))[0]
            s = jax.random.uniform(jax.random.fold_in(k, 3), (L, RANK))
            pw = {"U": U, "s": s}
        clients.append({"W": W})
        projs.append({"W": pw})
    return clients, projs


def _time_agg(clients, projs, cfg, backend, reps: int = 3):
    def fn():
        return maecho_aggregate(clients, projs, cfg,
                                stack_levels={"W": 1}, backend=backend)

    out = fn()                                  # compile
    _, us = timed(fn)
    for _ in range(reps - 1):
        _, u = timed(fn)
        us = min(us, u)
    return out, us


def _kernel_programs(clients, projs, cfg) -> int:
    """Distinct Pallas kernels in the traced aggregation (the jaxpr
    prints each jitted kernel's body once; per-layer launches would
    show up as L distinct programs or L-scaled call sites)."""
    txt = str(jax.make_jaxpr(
        lambda: maecho_aggregate(clients, projs, cfg,
                                 stack_levels={"W": 1},
                                 backend="kernel"))())
    return txt.count("pallas_call")


def _resid_metrics(L: int, kind: str) -> str:
    """Exact per-iteration residual HBM footprint, oracle vs kernel."""
    oracle_mb = 2 * N_CLIENTS * L * OUT_D * IN_D * F32 / 1e6
    if kind == "factored":
        kern_mb = 2 * N_CLIENTS * L * OUT_D * RANK * F32 / 1e6
        return (f"resid_mb_oracle={oracle_mb:.0f};"
                f"resid_mb_kernel={kern_mb:.0f};"
                f"resid_x={IN_D / RANK:.1f}")
    return (f"resid_mb_oracle={oracle_mb:.0f};resid_mb_kernel=0;"
            f"resid_x=streamed")


def run(quick: bool = False):
    Ls = [2, 4] if quick else [2, 4, 8, 16]
    kinds = ["factored"] if quick else ["factored", "diag"]
    ocfg = MAEchoConfig(tau=2, eta=0.5, qp_iters=60)
    kcfg = dataclasses.replace(ocfg, kernel_block=KERNEL_BLOCK)
    tag = f"{OUT_D}x{IN_D}_N{N_CLIENTS}"
    for kind in kinds:
        for L in Ls:
            clients, projs = _make_stacked(L, kind)
            w_o, us_o = _time_agg(clients, projs, ocfg, "oracle")
            w_k, us_k = _time_agg(clients, projs, kcfg, "kernel")
            err = float(jnp.max(jnp.abs(
                np.asarray(w_o["W"]) - np.asarray(w_k["W"]))))
            assert err < 1e-3, (
                f"stacked kernel diverged from oracle: {kind} L={L} "
                f"err={err}")
            programs = _kernel_programs(clients, projs, kcfg)
            assert programs == 3, (
                f"stacked launch contract broken: {programs} Pallas "
                f"kernels traced (want 3, independent of L={L})")
            row(f"stacked_agg/oracle_{kind}_L{L}_{tag}", us_o, "")
            row(f"stacked_agg/kernel_{kind}_L{L}_{tag}", us_k,
                f"parity={err:.1e};kernel_programs={programs};"
                + _resid_metrics(L, kind))
    print("# stacked_agg: kernel rows are interpret-mode dispatch "
          "trajectories (block=512); resid_x is the exact TPU-target "
          "residual-HBM win over the oracle path")


if __name__ == "__main__":
    run()
