"""Pallas TPU kernel: Gram matrix of projected MA-Echo residuals.

The Eq. 6 QP needs the (N, N) table  G[i, j] = ⟨Rᵢ, Rⱼ⟩  with
Rᵢ = (W − Vᵢ)Pᵢ.  The naive path materializes the full (N, out, in)
fp32 residual tensor in HBM just to contract it down to N² scalars.
This kernel streams instead: per (out, in) output tile it builds each
client's residual tile **in VMEM** — the (W − Vᵢ) difference is formed
in-register and contracted against Pᵢ's (bk, bi) blocks on the fly —
then folds all N×N pairwise tile dot products into an (N, N) VMEM
accumulator.  Nothing of size out×in is ever written to HBM.

Grid: (n_out, n_in, N, n_k).  The two inner axes build one client's
residual tile (k is the GEMM reduction over the projector's rows); the
finished tile is parked in the (N, bo, bi) ``rstore`` scratch, and once
all clients' tiles for this (o, j) position exist, one batched
double-contraction adds their pairwise products to the Gram
accumulator.  Scratch persists across the whole grid; the Gram table
is written exactly once, at the final step.

Variants (all share the accumulate/finalize tail):
  - ``maecho_gram``:          dense (N, in, in) projectors;
  - ``maecho_gram_factored``: Pᵢ = Uᵢ·diag(sᵢ)·Uᵢᵀ kept factored — the
    residual tile is Aᵢ @ Uᵢᵀ with Aᵢ = ((W − Vᵢ)Uᵢ)·diag(sᵢ) formed
    once as the (N, out, k) *compressed* residual, dropping the GEMM
    chain from O(out·in²) to O(out·in·k) (paper §7.3: projectors are
    low-rank);
  - ``maecho_gram_diag``:     1-D per-client diagonal projectors
    (embedding token support / broadcast scalar rule) — elementwise
    residuals, single fused pass, no reduction axis.

VMEM budget: rstore is N·bo·bi fp32 — with the default 128×128 blocks
that caps N around 40 per core (the paper runs N ≤ 50; shrink ``bo``
for larger cohorts).

Stacked-layer variants (``maecho_gram_stacked`` /
``maecho_gram_left_stacked`` / ``maecho_gram_diag_stacked``): the
scan-over-layers axis L is folded into the grid as the outermost
dimension — grid (L, n_out, n_in, N, n_k), per-layer (N, N) output
block, same VMEM scratch reused across layers — so ONE launch covers
every scanned layer of a stacked leaf (the LLM transformer-stack
layout) instead of L oracle fallbacks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_tail(resid, out_ref, racc_ref, rstore_ref, gacc_ref,
               n_clients: int, n_k: int, off: int = 0):
    """Shared accumulate/park/contract/finalize logic.

    ``resid`` is this (client, k-block)'s partial-residual contribution
    (bo, bi) in fp32; callers form it from their own operands.
    ``off`` is the grid offset of the (out, in, client, k) axes: 0 for
    the per-layer grid, 1 when a stacked-layer axis rides in front —
    the accumulators then re-initialize at the start of every layer
    (the (o, j, i, k) == 0 condition fires once per outer-grid step)
    and the finalize writes that layer's (N, N) output block.
    """
    o, j, i, k = (pl.program_id(off + t) for t in range(4))
    n_out, n_in = pl.num_programs(off), pl.num_programs(off + 1)

    @pl.when((o == 0) & (j == 0) & (i == 0) & (k == 0))
    def _init_gram():
        gacc_ref[...] = jnp.zeros_like(gacc_ref)

    @pl.when(k == 0)
    def _init_tile():
        racc_ref[...] = jnp.zeros_like(racc_ref)

    racc_ref[...] += resid

    @pl.when(k == n_k - 1)
    def _park_tile():
        rstore_ref[i] = racc_ref[...]

    @pl.when((i == n_clients - 1) & (k == n_k - 1))
    def _contract_pairs():
        r = rstore_ref[...]                       # (N, bo, bi)
        gacc_ref[...] += jax.lax.dot_general(
            r, r, (((1, 2), (1, 2)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when((o == n_out - 1) & (j == n_in - 1) &
             (i == n_clients - 1) & (k == n_k - 1))
    def _finalize():
        out_ref[...] = gacc_ref[...].astype(out_ref.dtype)


def _gram_kernel_dense(w_ref, v_ref, p_ref, out_ref,
                       racc_ref, rstore_ref, gacc_ref,
                       *, n_clients: int, n_k: int, off: int = 0):
    resid = jax.lax.dot((w_ref[...] - v_ref[...]).astype(jnp.float32),
                        p_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    _gram_tail(resid, out_ref, racc_ref, rstore_ref, gacc_ref,
               n_clients, n_k, off)


def _gram_kernel_left(a_ref, ut_ref, out_ref,
                      racc_ref, rstore_ref, gacc_ref,
                      *, n_clients: int, n_k: int, off: int = 0):
    """Residual given as a left factor: Rᵢ = Aᵢ @ (right)ᵢ."""
    resid = jax.lax.dot(a_ref[...].astype(jnp.float32),
                        ut_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    _gram_tail(resid, out_ref, racc_ref, rstore_ref, gacc_ref,
               n_clients, n_k, off)


@functools.partial(jax.jit, static_argnames=("bo", "bi", "bk",
                                             "interpret"))
def maecho_gram(W, V, P, *, bo: int = 128, bi: int = 128, bk: int = 128,
                interpret: bool = True):
    """W: (out, in); V: (N, out, in); P: (N, in, in) dense.

    Returns the fp32 (N, N) Gram matrix of projected residuals.
    """
    out_d, in_d = W.shape
    N = V.shape[0]
    bo, bi, bk = min(bo, out_d), min(bi, in_d), min(bk, in_d)
    assert out_d % bo == 0 and in_d % bi == 0 and in_d % bk == 0, (
        "pad layer dims to block multiples (ops.maecho_gram_auto)")
    n_out, n_in, n_k = out_d // bo, in_d // bi, in_d // bk
    kernel = functools.partial(_gram_kernel_dense, n_clients=N, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(n_out, n_in, N, n_k),
        in_specs=[
            pl.BlockSpec((bo, bk), lambda o, j, i, k: (o, k)),          # W
            pl.BlockSpec((None, bo, bk), lambda o, j, i, k: (i, o, k)),  # V
            pl.BlockSpec((None, bk, bi), lambda o, j, i, k: (i, k, j)),  # P
        ],
        out_specs=pl.BlockSpec((N, N), lambda o, j, i, k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bo, bi), jnp.float32),
                        pltpu.VMEM((N, bo, bi), jnp.float32),
                        pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(W, V, P)


def compressed_residual(W, V, U, s):
    """Aᵢ = ((W − Vᵢ)Uᵢ)·diag(sᵢ): the (N, …, out, k) compressed
    residual.

    Formed as W@Uᵢ − Vᵢ@Uᵢ so the (N, …, out, in) full residual is
    never materialized — only its rank-k image, which IS the
    factored-path working set.  Any stacked-layer axes ride the
    ellipsis: W (…, out, in), V (N, …, out, in), U (N, …, in, k),
    s (N, …, k).
    """
    A = (jnp.einsum("...oi,n...ik->n...ok", W.astype(jnp.float32),
                    U.astype(jnp.float32))
         - jnp.einsum("n...oi,n...ik->n...ok", V.astype(jnp.float32),
                      U.astype(jnp.float32)))
    return A * s[..., None, :].astype(jnp.float32)


def maecho_gram_factored(W, V, U, s, *, bo: int = 128, bi: int = 128,
                         bk: int = 128, interpret: bool = True):
    """Factored projectors Pᵢ = Uᵢ·diag(sᵢ)·Uᵢᵀ.

    W: (out, in); V: (N, out, in); U: (N, in, k); s: (N, k).
    The kernel streams Rᵢ tiles as Aᵢ @ Uᵢᵀ (reduction over k, not in).
    """
    A = compressed_residual(W, V, U, s)
    UT = jnp.swapaxes(U, 1, 2).astype(jnp.float32)       # (N, k, in)
    return maecho_gram_left(A, UT, bo=bo, bi=bi, bk=bk,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bo", "bi", "bk",
                                             "interpret"))
def maecho_gram_left(A, UT, *, bo: int = 128, bi: int = 128,
                     bk: int = 128, interpret: bool = True):
    """Gram from pre-factored residuals Rᵢ = Aᵢ @ UTᵢ.

    A: (N, out, k) compressed residual; UT: (N, k, in).  Callers that
    also run the Eq. 7 update can share one ``compressed_residual``.
    """
    N, out_d, kd = A.shape
    in_d = UT.shape[2]
    bo, bi, bk = min(bo, out_d), min(bi, in_d), min(bk, kd)
    assert out_d % bo == 0 and in_d % bi == 0 and kd % bk == 0, (
        "pad layer dims / rank to block multiples")
    n_out, n_in, n_k = out_d // bo, in_d // bi, kd // bk
    kernel = functools.partial(_gram_kernel_left, n_clients=N, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(n_out, n_in, N, n_k),
        in_specs=[
            pl.BlockSpec((None, bo, bk), lambda o, j, i, k: (i, o, k)),  # A
            pl.BlockSpec((None, bk, bi), lambda o, j, i, k: (i, k, j)),  # Uᵀ
        ],
        out_specs=pl.BlockSpec((N, N), lambda o, j, i, k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bo, bi), jnp.float32),
                        pltpu.VMEM((N, bo, bi), jnp.float32),
                        pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(A, UT)


def _gram_cross_kernel(a_ref, b_ref, out_ref, acc_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(0) - 1)
    def _finalize():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def maecho_gram_cross(Ra, Rb, *, bd: int = 512, interpret: bool = True):
    """Cross-Gram block between two client chunks' flat residuals.

    Ra: (ca, D); Rb: (cb, D) — flattened residual rows for chunks a and
    b.  Returns the fp32 (ca, cb) block G[i, j] = ⟨Ra_i, Rb_j⟩ by
    streaming the feature axis through VMEM in ``bd``-wide slabs (the
    ``rank_update.py`` tiled-accumulator idiom): only one (ca, bd) +
    (cb, bd) operand pair is resident per grid step, never the full
    (N, D) residual set — the client-chunked Gram path's building
    block.
    """
    ca, D = Ra.shape
    cb = Rb.shape[0]
    bd = min(bd, D)
    assert D % bd == 0, "caller pads the flat feature axis to bd"
    return pl.pallas_call(
        _gram_cross_kernel,
        grid=(D // bd,),
        in_specs=[pl.BlockSpec((ca, bd), lambda k: (0, k)),
                  pl.BlockSpec((cb, bd), lambda k: (0, k))],
        out_specs=pl.BlockSpec((ca, cb), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ca, cb), jnp.float32),
        scratch_shapes=[pltpu.VMEM((ca, cb), jnp.float32)],
        interpret=interpret,
    )(Ra, Rb)


def _gram_diag_kernel(w_ref, v_ref, p_ref, out_ref, gacc_ref,
                      *, n_clients: int, off: int = 0):
    o, j = pl.program_id(off), pl.program_id(off + 1)
    n_out, n_in = pl.num_programs(off), pl.num_programs(off + 1)

    @pl.when((o == 0) & (j == 0))
    def _init():
        gacc_ref[...] = jnp.zeros_like(gacc_ref)

    w = w_ref[...].astype(jnp.float32)                   # (bo, bi)
    v = v_ref[...].astype(jnp.float32)                   # (N, bo, bi)
    p = p_ref[...].astype(jnp.float32)                   # (N, 1, bi)
    r = (w[None] - v) * p
    gacc_ref[...] += jax.lax.dot_general(
        r, r, (((1, 2), (1, 2)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when((o == n_out - 1) & (j == n_in - 1))
    def _finalize():
        out_ref[...] = gacc_ref[...].astype(out_ref.dtype)


# --------------------------------------------------------------------------
# stacked-layer variants: the scan-layer axis L rides the grid in front,
# one launch per leaf covers all L layers (per-layer (N, N) output block,
# per-layer accumulator re-init — see _gram_tail's ``off``)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("bo", "bi", "bk",
                                             "interpret"))
def maecho_gram_stacked(W, V, P, *, bo: int = 128, bi: int = 128,
                        bk: int = 128, interpret: bool = True):
    """W: (L, out, in); V: (N, L, out, in); P: (N, L, in, in) dense.

    Returns the fp32 (L, N, N) per-layer Gram stack from ONE launch:
    grid (L, n_out, n_in, N, n_k) with the layer axis outermost, so
    the VMEM scratch (one layer's tile accumulators) is reused across
    layers instead of replicated."""
    L, out_d, in_d = W.shape
    N = V.shape[0]
    bo, bi, bk = min(bo, out_d), min(bi, in_d), min(bk, in_d)
    assert out_d % bo == 0 and in_d % bi == 0 and in_d % bk == 0, (
        "pad layer dims to block multiples (ops stacked wrappers)")
    n_out, n_in, n_k = out_d // bo, in_d // bi, in_d // bk
    kernel = functools.partial(_gram_kernel_dense, n_clients=N, n_k=n_k,
                               off=1)
    return pl.pallas_call(
        kernel,
        grid=(L, n_out, n_in, N, n_k),
        in_specs=[
            pl.BlockSpec((None, bo, bk),
                         lambda l, o, j, i, k: (l, o, k)),             # W
            pl.BlockSpec((None, None, bo, bk),
                         lambda l, o, j, i, k: (i, l, o, k)),          # V
            pl.BlockSpec((None, None, bk, bi),
                         lambda l, o, j, i, k: (i, l, k, j)),          # P
        ],
        out_specs=pl.BlockSpec((None, N, N),
                               lambda l, o, j, i, k: (l, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((L, N, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bo, bi), jnp.float32),
                        pltpu.VMEM((N, bo, bi), jnp.float32),
                        pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(W, V, P)


@functools.partial(jax.jit, static_argnames=("bo", "bi", "bk",
                                             "interpret"))
def maecho_gram_left_stacked(A, UT, *, bo: int = 128, bi: int = 128,
                             bk: int = 128, interpret: bool = True):
    """Stacked Gram from pre-factored residuals Rₗᵢ = Aₗᵢ @ UTₗᵢ.

    A: (N, L, out, k) compressed residual; UT: (N, L, k, in).
    Returns (L, N, N); the compressed residual is shared with the
    stacked Eq. 7 kernel exactly like the per-layer path."""
    N, L, out_d, kd = A.shape
    in_d = UT.shape[3]
    bo, bi, bk = min(bo, out_d), min(bi, in_d), min(bk, kd)
    assert out_d % bo == 0 and in_d % bi == 0 and kd % bk == 0, (
        "pad layer dims / rank to block multiples")
    n_out, n_in, n_k = out_d // bo, in_d // bi, kd // bk
    kernel = functools.partial(_gram_kernel_left, n_clients=N, n_k=n_k,
                               off=1)
    return pl.pallas_call(
        kernel,
        grid=(L, n_out, n_in, N, n_k),
        in_specs=[
            pl.BlockSpec((None, None, bo, bk),
                         lambda l, o, j, i, k: (i, l, o, k)),          # A
            pl.BlockSpec((None, None, bk, bi),
                         lambda l, o, j, i, k: (i, l, k, j)),          # Uᵀ
        ],
        out_specs=pl.BlockSpec((None, N, N),
                               lambda l, o, j, i, k: (l, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((L, N, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bo, bi), jnp.float32),
                        pltpu.VMEM((N, bo, bi), jnp.float32),
                        pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(A, UT)


@functools.partial(jax.jit, static_argnames=("bo", "bi", "interpret"))
def maecho_gram_diag_stacked(W, V, p, *, bo: int = 128, bi: int = 128,
                             interpret: bool = True):
    """Stacked diagonal projectors.  W: (L, out, in);
    V: (N, L, out, in); p: (N, L, in).  Returns (L, N, N)."""
    L, out_d, in_d = W.shape
    N = V.shape[0]
    bo, bi = min(bo, out_d), min(bi, in_d)
    assert out_d % bo == 0 and in_d % bi == 0, (
        "pad layer dims to block multiples")
    p4 = p.reshape(N, L, 1, in_d)
    kernel = functools.partial(_gram_diag_kernel, n_clients=N, off=1)
    return pl.pallas_call(
        kernel,
        grid=(L, out_d // bo, in_d // bi),
        in_specs=[
            pl.BlockSpec((None, bo, bi), lambda l, o, j: (l, o, j)),   # W
            pl.BlockSpec((N, None, bo, bi),
                         lambda l, o, j: (0, l, o, j)),                # V
            pl.BlockSpec((N, None, 1, bi),
                         lambda l, o, j: (0, l, 0, j)),                # p
        ],
        out_specs=pl.BlockSpec((None, N, N), lambda l, o, j: (l, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((L, N, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(W, V, p4)


@functools.partial(jax.jit, static_argnames=("bo", "bi", "interpret"))
def maecho_gram_diag(W, V, p, *, bo: int = 128, bi: int = 128,
                     interpret: bool = True):
    """Diagonal projectors.  W: (out, in); V: (N, out, in); p: (N, in)."""
    out_d, in_d = W.shape
    N = V.shape[0]
    bo, bi = min(bo, out_d), min(bi, in_d)
    assert out_d % bo == 0 and in_d % bi == 0, (
        "pad layer dims to block multiples")
    p3 = p.reshape(N, 1, in_d)
    kernel = functools.partial(_gram_diag_kernel, n_clients=N)
    return pl.pallas_call(
        kernel,
        grid=(out_d // bo, in_d // bi),
        in_specs=[
            pl.BlockSpec((bo, bi), lambda o, j: (o, j)),           # W
            pl.BlockSpec((N, bo, bi), lambda o, j: (0, o, j)),     # V
            pl.BlockSpec((N, 1, bi), lambda o, j: (0, 0, j)),      # p
        ],
        out_specs=pl.BlockSpec((N, N), lambda o, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(W, V, p3)
