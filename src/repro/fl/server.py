"""Server-side one-shot aggregation orchestration (no training — the
paper's setting).  Handles the conv-kernel reshape (paper §5.2:
``(C_out, C_in, h, w) -> (C_out, C_in·h·w)``) so the layer-wise
algebra in ``repro.core`` only ever sees 2-D weight leaves.
"""
from __future__ import annotations

from typing import Optional

from repro.core import aggregators
from repro.core.maecho import MAEchoConfig
from repro.fl import models as pm


def _flatten_convs(params):
    shapes = {}

    def walk(layers):
        out = []
        for i, lay in enumerate(layers):
            if lay["W"].ndim == 4:
                c = lay["W"].shape[0]
                shapes[i] = lay["W"].shape
                out.append({**lay, "W": lay["W"].reshape(c, -1)})
            else:
                out.append(lay)
        return out

    if isinstance(params, dict) and "dec" in params:
        return {"dec": walk(params["dec"])}, shapes
    return walk(params), shapes


def _unflatten_convs(params, shapes):
    def walk(layers):
        out = []
        for i, lay in enumerate(layers):
            if i in shapes:
                out.append({**lay, "W": lay["W"].reshape(shapes[i])})
            else:
                out.append(lay)
        return out

    if isinstance(params, dict) and "dec" in params:
        return {"dec": walk(params["dec"])}
    return walk(params)


def one_shot_aggregate(
    spec: pm.PaperModelSpec,
    client_params: list,
    projections: Optional[list] = None,
    method: str = "maecho",
    cfg: MAEchoConfig = None,
    **kw,
):
    """Run one aggregation operator.  ``client_params`` in model layout
    (conv weights 4-D); projections from ``fl.client.compute_projections``.

    Extra ``**kw`` flows through to the operator — for ``maecho``
    that includes ``backend`` (``"oracle"`` | ``"kernel"`` | ``"auto"``
    | ``"sharded"``), ``mesh`` (the device mesh for the sharded
    pipeline) and ``client_mask`` (ragged participation); see
    ``core.maecho.maecho_aggregate``.
    """
    flat, shapes = zip(*[_flatten_convs(p) for p in client_params])
    shapes = shapes[0]
    flat = list(flat)

    if method == "fedavg":
        out = aggregators.fedavg(flat)
    elif method == "ot":
        layers = [f if isinstance(f, list) else f["dec"] for f in flat]
        out = aggregators.ot_average(layers)
        if not isinstance(flat[0], list):
            out = {"dec": out}
    elif method == "maecho":
        out = aggregators.maecho(flat, projections, cfg, **kw)
    elif method == "maecho+ot":
        layers = [f if isinstance(f, list) else f["dec"] for f in flat]
        projs = [p if isinstance(p, list) else p["dec"]
                 for p in projections]
        out_layers = aggregators.maecho_ot(layers, projs, cfg, **kw)
        out = (out_layers if isinstance(flat[0], list)
               else {"dec": out_layers})
    else:
        raise ValueError(f"unknown method {method!r}")

    return _unflatten_convs(out, shapes)
