"""Model configuration shared by every architecture family.

One dataclass covers all six families (dense / moe / ssm / hybrid /
encdec / vlm); family-specific sub-configs are optional fields.  Every
assigned architecture in ``repro.configs`` instantiates this with the
exact published numbers and cites its source.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8            # routed experts
    top_k: int = 2
    n_shared_experts: int = 0     # always-on shared experts (qwen2-moe)
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2
    # token group size for grouped dispatch (keeps dispatch FLOPs local);
    # see roofline §Perf for the hillclimb on this knob.
    group_size: int = 4096
    # "einsum": Switch-style one-hot dispatch/combine matmuls (paper-era
    # baseline); "gather": scatter/gather dispatch with zero matmul
    # FLOPs (§Perf hillclimb H1 — 6.6× dispatch-FLOPs removal)
    dispatch_mode: str = "einsum"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2               # d_inner = expand * d_model
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)  (mamba1)
    version: int = 1              # 1 = mamba1 (falcon-mamba), 2 = mamba2 (zamba2)
    head_dim: int = 64            # mamba2 only
    chunk: int = 256              # mamba2 SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def dt_rank_(self, d_model: int) -> int:
        return self.dt_rank or max(1, (d_model + 15) // 16)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """zamba2-style: shared attention block interleaved with mamba2."""
    attn_every: int = 6           # one shared-attn call per this many ssm layers
    shared_attn_blocks: int = 1   # number of distinct shared blocks (round-robin)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """whisper-style encoder-decoder backbone (conv frontend stubbed)."""
    n_enc_layers: int = 4
    enc_seq: int = 1500           # encoder positions (whisper 30s -> 1500)
    dec_seq: int = 448            # decoder text positions for train/prefill


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    """phi-3-vision style: LM backbone consumes stub patch embeddings."""
    n_patches: int = 1024         # vision tokens prepended to text
    d_vision: int = 1024          # stub vision-encoder output dim (projected)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""              # citation for the config numbers

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # attention memory policy
    attn_chunk_q: int = 512       # flash-style query block
    attn_chunk_k: int = 1024      # flash-style kv block
    window: int = 8192            # sliding-window size used for long-context decode
    # attention backend: "auto" (Pallas kernels when they win, jnp
    # otherwise), "kernel" (force Pallas, warn-once fallback when the
    # shape is inexpressible), "oracle" (always the jnp reference paths)
    attn_backend: str = "auto"

    # distribution policy
    fsdp: bool = False            # shard weights over the data axis too
    remat: bool = True            # checkpoint per scanned layer
    microbatches: int = 1         # grad-accumulation steps per train_step
    seq_shard: bool = False       # shard train activations over seq (model ax)

    # roofline-probe knobs (see repro.roofline.probe): unrolled scans and
    # associative SSM scan give loop-free HLO whose cost_analysis is exact
    unroll_layers: bool = False
    ssm_assoc: bool = False

    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ----- parameter counting (for roofline MODEL_FLOPS = 6 N D) -----
    def n_params(self) -> int:
        d, f, V = self.d_model, self.d_ff, self.vocab
        hd, Hq, Hkv = self.hd(), self.n_heads, self.n_kv_heads
        n = V * d                                    # embed
        if not self.tie_embeddings:
            n += V * d                               # lm head
        if self.family in ("dense", "vlm", "moe"):
            attn = d * Hq * hd + 2 * d * Hkv * hd + Hq * hd * d
            if self.family == "moe":
                m = self.moe
                ffn_one = 3 * d * f                  # swiglu expert
                ffn = (m.n_experts + m.n_shared_experts) * ffn_one + d * m.n_experts
            else:
                ffn = 3 * d * f
            n += self.n_layers * (attn + ffn + 2 * d)
        elif self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            r = s.dt_rank_(d)
            per = (d * 2 * di + di * s.d_conv + di * (r + 2 * s.d_state)
                   + r * di + di * s.d_state + di + di * d + d)
            n += self.n_layers * per
        elif self.family == "hybrid":
            s = self.ssm
            di = s.d_inner(d)
            nh = di // s.head_dim
            per = (d * (2 * di + 2 * nh * s.d_state + nh) + di * s.d_conv
                   + nh + di + di * d + d + 3 * d * f + 2 * d)
            n += self.n_layers * per
            attn = d * Hq * self.hd() * 2 + 2 * d * Hkv * self.hd() + 2 * d
            n += (self.hybrid.shared_attn_blocks if self.hybrid else 1) * attn
        elif self.family == "encdec":
            e = self.encdec
            attn = d * Hq * hd + 2 * d * Hkv * hd + Hq * hd * d
            per_dec = 2 * attn + 2 * d * f + 3 * d    # self + cross + mlp(gelu)
            per_enc = attn + 2 * d * f + 2 * d
            n += self.n_layers * per_dec + e.n_enc_layers * per_enc
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.family != "moe":
            return self.n_params()
        m = self.moe
        d, f = self.d_model, self.d_ff
        total = self.n_params()
        inactive = self.n_layers * (m.n_experts - m.top_k) * 3 * d * f
        return total - inactive


# Input-shape suite assigned to this paper (public pool).
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
