"""phi-3-vision-4.2b — phi3-mini LM backbone + stub CLIP frontend
[hf:microsoft/Phi-3-vision-128k-instruct]. 32L d_model=3072 32H MHA
(kv=32) d_ff=8192 vocab=32064; vision tokens provided as embeddings."""
from repro.configs.common import smoke_reduce
from repro.models.config import ModelConfig, VLMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32064, head_dim=96,
        vlm=VLMConfig(n_patches=1024, d_vision=1024),
        microbatches=8,
        source="hf:microsoft/Phi-3-vision-128k-instruct",
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config(), n_heads=4, n_kv_heads=4)
