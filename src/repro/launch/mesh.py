"""Production mesh factory.

Defined as FUNCTIONS (not module constants) so importing this module
never touches jax device state; the dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import, and smoke tests/benches see the real single device.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips per pod; 2 pods = 512 when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devs)} — run "
            "under the dry-run driver (repro.launch.dryrun) which forces "
            "512 host platform devices")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_debug_mesh(n_data: int = 1, n_model: int = 1) -> Mesh:
    """Tiny mesh (tests / forced-host-device smokes).

    A device shortfall is an error naming the gap — like
    ``make_production_mesh`` — instead of the old silent truncation
    (which either reshaped fewer devices into the wrong mesh or died
    in an opaque numpy reshape)."""
    need = n_data * n_model
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"debug mesh ({n_data}, {n_model}) needs {need} devices, "
            f"found {len(devs)} — force host platform devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            "before the first jax import (cf. repro.launch.dryrun)")
    return Mesh(np.asarray(devs[:need]).reshape(n_data, n_model),
                ("data", "model"))
