"""MA-Echo — Algorithm 1 of the paper, as a composable JAX op.

Operates on *pytrees of layers*: each client contributes a pytree of
weight leaves plus a structurally matching pytree of projection leaves.
Faithful to the paper:

  W⁽⁰⁾ = init (vanilla average by default);  Vᵢ = Wᵢ
  repeat τ times, per layer l:
      Rᵢ  = (W − Vᵢ) Pᵢ                    (residual in client i's row space)
      α*  = argmin ½‖Σᵢ 2αᵢ Rᵢ‖²  on the capped simplex   (Eq. 6)
      W  += η · ( −Σᵢ 2αᵢ* Rᵢ )                            (Eq. 7)
      Vᵢ += Norm( (W − Vᵢ)(I − μ/(1+μ) Pᵢ) )              (Eq. 11)

Projection leaves may be:
  - 2-D (d_in, d_in): full projector (paper's form);
  - 1-D matching the in-axis: diagonal projector (used for embedding
    tables where the input space is the one-hot vocabulary — P is the
    client's token-support indicator);
  - scalar 1.0: full-rank "input is always live" projector, the bias /
    norm-parameter rule (DESIGN.md §4);
  - any of the above with a leading stacked-layer axis L, matching a
    weight leaf (L, …) — the scan-over-layers LLM layout.  The QP is
    then solved per scanned layer (vmap), exactly like the paper's
    per-layer loop.

Weight-leaf convention: ``convention="oi"`` (paper: W is (out, in), the
MLP/CNN models) or ``"io"`` (the LLM zoo: x @ W, W is (in, out)).

Backends — the ``backend`` argument of :func:`maecho_aggregate`:

  - ``"oracle"`` (default): the reference jnp path below.  Each outer
    iteration materializes the full (N, out, in) fp32 residual tensor
    Rᵢ = (W − Vᵢ)Pᵢ twice (once for the Eq. 6/7 Gram+update, once
    re-projected for Eq. 11) — 2·N·out·in fp32 of HBM traffic per
    layer per iteration that exists only to be contracted away.
  - ``"kernel"``: the fused streaming pipeline.  Eligible leaves (2-D
    weights, with or without leading stacked-layer axes) run three
    Pallas passes per iteration — ``maecho_gram`` (Eq. 6 Gram,
    residual tiles formed in VMEM and contracted on the fly),
    ``maecho_update`` (Eq. 7) and ``maecho_v_update`` (Eq. 11) — so
    no residual tensor is ever resident in HBM.  A stacked leaf's
    layer axes are flattened into the kernel grid's outermost
    dimension (one launch per pass covers all L scanned layers — the
    ``*_stacked`` kernels); factored ``{"U", "s"}`` projectors stay
    factored through the compute: the (N, [L,] out, k) compressed
    residual replaces the full one and every GEMM chain drops from
    O(out·in²) to O(out·in·k).  Ineligible leaves (1-D biases, shapes
    below one tile) fall back to the oracle — dispatch happens at
    trace time, the whole τ-loop still jits as one program, and the
    fallback is surfaced once via ``ops.fallback_warn``.
  - ``"auto"``: ``"kernel"`` for leaves big enough to tile
    (min trailing dim ≥ 128), ``"oracle"`` otherwise.
  - ``"sharded"``: the mesh-sharded pipeline.  Eligible leaves (2-D
    weights, stacked or not, out-dim tile count divisible by the
    mesh-axis size — ``ops.sharded_ok``) run the streaming gram/apply
    under ``shard_map`` over ``MAEchoConfig.mesh_axis``: each device
    owns an out-row shard, forms only its residual tiles, and ONE
    ``psum`` per leaf per outer iteration reconstructs the Gram —
    (N, N), or the whole (L, N, N) stack for a stacked leaf whose
    layer axis rides the grid; the stacked QP solve stays global and
    the Eq. 7/11 applies run purely on the owned rows
    (compressed-residual reuse intact).  Ineligible leaves degrade to
    the single-device ``"auto"`` dispatch.  Pass the mesh via
    ``maecho_aggregate(..., mesh=...)`` (default: a 1-D mesh over
    every visible device).
  - ``"sharded2d"``: the 2-D (out × in) mesh-sharded pipeline.
    Eligible leaves (``rules.sharded_ok2d`` — BOTH trailing dims'
    tile counts divide their axis group) split out-rows over
    ``MAEchoConfig.mesh_axis`` AND in-columns over
    ``MAEchoConfig.mesh_in_axis`` ("model"): each device forms only
    its (out/osz, in/isz) residual tile, partial Grams are psum'd
    over BOTH axis groups in ONE collective per leaf per outer
    iteration, and the applies stay row/col-local.  This covers
    leaves whose out-dim alone is too small to span the fleet — the
    device count factors as osz × isz instead of dividing the
    out-tiles 1-D.  Leaves that fail the 2-D gate degrade to the 1-D
    ``"sharded"`` shard over ``mesh_axis``, then to the ``"auto"``
    rule (each fallback warned once).

Routing is compiled ONCE per (treedef, shapes, conventions,
stack_levels, backend, mesh, config) by ``core.plan.compile_plan``
into a frozen ``AggPlan`` — one ``LeafPlan`` per leaf carrying the
route, kernel layout, effective tile size and psum axes.  The outer
loop below is a pure executor over that plan, and
:func:`dispatch_summary` is a view of the same compiled object, so
the coverage it reports is definitionally the coverage that runs.

Ragged participation (``maecho_aggregate(..., client_mask=...)``): an
optional per-leaf boolean client mask rides the batched QP's validity
masking — masked-out clients get exactly α = 0 (their residuals never
touch the Eq. 7 update), their anchors Vᵢ are frozen, and the result
matches aggregating the participating subset alone (same init point).

The QP and the padding logic (``repro.kernels.ops._pad_to``, zero
padding is exact for all three passes) are shared between backends;
``REPRO_PALLAS_INTERPRET`` selects interpret-mode kernel execution
(this container) vs real TPU lowering.

Batched QP (``MAEchoConfig.qp_batched``, default on): each outer
iteration runs in three phases — every leaf (and every scanned layer
of a stacked leaf) first emits its (N, N) Gram into one stacked
(L, N, N) tensor, a **single** vmapped PGD solve
(``qp.solve_qp_batched``) produces all τ vectors at once, and the
α rows are scattered back through the per-leaf Eq. 7 / Eq. 11 updates
(reusing the residual / compressed-residual context computed in the
gram phase).  ``qp_batched=False`` restores the sequential
one-PGD-per-leaf loop — same math, L solves instead of one.

Memory trade-off: the batched path keeps every leaf's reuse context
(on the oracle backend, the (N, out, in) fp32 residual) live across
the stacked solve, so peak residency grows from one leaf's residual
to ~N× the whole model in fp32.  Fine for the paper-scale models
this τ-loop targets; for LLM-scale trees where that doesn't fit, set
``qp_batched=False`` (sequential frees each leaf's residual before
the next gram) or use the factored/kernel paths whose contexts are
the (N, out, k) compressed residuals.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod
from repro.core import qp as qp_mod
from repro.core.plan import AggPlan, LeafPlan, compile_plan
from repro.utils import trees

Pytree = Any


@dataclasses.dataclass(frozen=True)
class MAEchoConfig:
    tau: int = 30                 # outer iterations
    eta: float = 1.0              # step size on W
    C: float = 1.0                # simplex cap (paper: C ∈ [1/N, 1])
    mu: float = 1.0               # Eq. 8 penalty; factor μ/(1+μ)
    norm: bool = False            # Norm(·) row-normalisation of V updates
    qp_iters: int = 200
    init: str = "average"         # average | first | random
    eps: float = 1e-12
    qp_batched: bool = True       # one stacked PGD solve per outer iter
    mesh_axis: str = "data"       # out-row shard axis ("sharded"/"2d")
    mesh_in_axis: str = "model"   # in-column shard axis ("sharded2d")
    # kernel tile edge for the (non-sharded) streaming pipeline;
    # 0 = ops.DEFAULT_BLOCK (128, the TPU-safe MXU tile).  Bigger
    # blocks shrink the grid — the interpret-mode benches use 512 to
    # amortize per-step interpreter overhead; on TPU stay within VMEM
    # (the gram rstore is N·bo·bi fp32).  The sharded pipeline keeps
    # DEFAULT_BLOCK (its out-padding granularity is block × axis_size).
    kernel_block: int = 0
    # client-axis chunk for the Gram/apply sweeps; 0 = unchunked.  When
    # set, eligible leaves accumulate their (N, N) Gram over blocks of
    # ``client_chunk`` clients (only that many residuals resident per
    # step — the cross-device large-N mode) and the QP tiles its
    # Gram-vector products over the same block edge.  Clamped to N per
    # leaf at plan time; composes with "sharded" (rows × client
    # blocks) but not "sharded2d" (degrades to the 1-D shard, warned).
    client_chunk: int = 0


# --------------------------------------------------------------------------
# per-leaf algebra
# --------------------------------------------------------------------------
def _apply_P(delta, P, convention: str):
    """delta·P respecting the in-axis convention and P's kind.

    P kinds: scalar (bias rule), 1-D diag (embedding token support),
    2-D full matrix, or FACTORED {"U": (in, k), "s": (k,)} with
    P = U·diag(s)·Uᵀ — the beyond-paper optimisation (EXPERIMENTS.md
    §Perf H3): the Eq. 7 GEMM chain drops from O(out·in²) to
    O(out·in·k), and communication from in² to in·(k+1) (paper Table 6
    shows the projectors are low-rank; we keep them factored through
    the *compute*, not just the wire).
    """
    if isinstance(P, dict):                 # factored projector
        U = P["U"]
        s = P["s"]
        if delta.ndim == 1:
            return ((delta @ U) * s) @ U.T
        if convention == "oi":
            return ((delta @ U) * s) @ U.T  # (out,k)·(k)·(k,in)
        return U @ (s[:, None] * (U.T @ delta))
    if P.ndim == 0:                         # full projector (bias rule)
        return delta * P
    if P.ndim == 1:                         # diagonal projector on in-axis
        if delta.ndim == 1:
            return delta * P
        return delta * (P[None, :] if convention == "oi" else P[:, None])
    # full matrix projector
    if delta.ndim == 1:
        return delta @ P
    if convention == "oi":
        return delta @ P                    # (out,in)@(in,in)
    return P @ delta                        # (in,in)@(in,out)


def _qp_alpha(G, cfg: MAEchoConfig, mask=None):
    """Eq. 6 dual QP for the sequential (per-leaf) path.  Delegates to
    ``qp.solve_qp`` — the same ``_pgd_masked`` body the batched solver
    vmaps, so batched/sequential parity is structural, not maintained
    by hand.  (The jitted wrapper traces inline under the enclosing
    jit; the whole aggregation still compiles as one program.)
    ``mask`` is the leaf's participation mask (ragged cohorts)."""
    return qp_mod.solve_qp(G, cfg.C, iters=cfg.qp_iters, mask=mask,
                           row_block=cfg.client_chunk)


def _flatten_stack(W, V, P, levels: int):
    """Collapse ``levels`` leading stacked-layer axes into one flat L
    axis for the stacked kernel grid.  Returns ``(Wf, Vf, Pf, lead)``
    with Wf (L, out, in), Vf (N, L, out, in), Pf stacked per kind, and
    ``lead`` the original leading shape for un-flattening."""
    lead = W.shape[:levels]
    Wf = W.reshape((-1,) + W.shape[levels:])
    Vf = V.reshape(V.shape[:1] + (-1,) + V.shape[1 + levels:])

    def flat_p(x):
        return x.reshape(x.shape[:1] + (-1,) + x.shape[1 + levels:])

    Pf = ({k: flat_p(v) for k, v in P.items()} if isinstance(P, dict)
          else flat_p(P))
    return Wf, Vf, Pf, lead


def _to_kernel_layout(W, V, P, convention: str, levels: int = 0):
    """The kernel pipelines are "oi"-native; "io" leaves are transposed
    around the call (XLA fuses the transposes into the kernels' operand
    loads).  Shared by the streaming and sharded gram halves — one copy
    of the layout contract; stacked leaves transpose the trailing two
    axes only."""
    if convention != "io":
        return W, V, P
    # oracle applies delta·P from the left for "io": (PᵢΔ)ᵀ = ΔᵀPᵢᵀ
    Pk = jnp.swapaxes(P, -1, -2) if (not isinstance(P, dict)
                                     and P.ndim == 3 + levels) else P
    return jnp.swapaxes(W, -1, -2), jnp.swapaxes(V, -1, -2), Pk


def _leaf_gram_kernel(W, V, P, cfg: MAEchoConfig, convention: str,
                      block: int):
    """Gram half of the fused streaming pipeline: the Eq. 6 Gram plus
    the padded-operand reuse context (padding/kind dispatch and the
    factored-path compressed-residual sharing live in
    ``ops.maecho_streaming_gram``).  ``block`` is the leaf plan's
    effective tile edge — the plan is the one source of the tiling,
    so the summary can never drift from what executes."""
    from repro.kernels import ops

    Wk, Vk, Pk = _to_kernel_layout(W, V, P, convention)
    return ops.maecho_streaming_gram(Wk, Vk, Pk, block=block)


def _leaf_apply_kernel(alpha, ctx, cfg: MAEchoConfig, convention: str,
                       block: int):
    """Update half of the fused streaming pipeline: Eq. 7 + Eq. 11 on
    the context from :func:`_leaf_gram_kernel`."""
    from repro.kernels import ops

    W_new, V_new = ops.maecho_streaming_apply(
        alpha, ctx, eta=cfg.eta, frac=cfg.mu / (1.0 + cfg.mu),
        norm=cfg.norm, eps=cfg.eps, block=block)
    if convention == "io":
        return W_new.T, jnp.swapaxes(V_new, 1, 2)
    return W_new, V_new


def _leaf_gram_sharded(W, V, P, cfg: MAEchoConfig, convention: str,
                       mesh):
    """Gram half of the mesh-sharded pipeline: the shared "oi"-native
    layout contract (:func:`_to_kernel_layout`), with the out-rows
    shard_map'd over ``cfg.mesh_axis`` (one Gram psum)."""
    from repro.kernels import ops

    Wk, Vk, Pk = _to_kernel_layout(W, V, P, convention)
    return ops.maecho_sharded_gram(Wk, Vk, Pk, mesh=mesh,
                                   axis=cfg.mesh_axis)


def _leaf_gram_sharded2d(W, V, P, cfg: MAEchoConfig, convention: str,
                         mesh):
    """Gram half of the 2-D (out × in) sharded pipeline: one partial
    Gram per (out, in) tile, psum'd over BOTH axis groups at once."""
    from repro.kernels import ops

    Wk, Vk, Pk = _to_kernel_layout(W, V, P, convention)
    return ops.maecho_sharded2d_gram(Wk, Vk, Pk, mesh=mesh,
                                     axis_out=cfg.mesh_axis,
                                     axis_in=cfg.mesh_in_axis)


def _leaf_apply_sharded2d(alpha, ctx, cfg: MAEchoConfig,
                          convention: str, mesh):
    """Update half of the 2-D sharded pipeline: Eq. 7 + Eq. 11 stay
    row/col-local — no collectives (the gram's two-axis psum is the
    leaf's only one per outer iteration)."""
    from repro.kernels import ops

    W_new, V_new = ops.maecho_sharded2d_apply(
        alpha, ctx, mesh=mesh, axis_out=cfg.mesh_axis,
        axis_in=cfg.mesh_in_axis, eta=cfg.eta,
        frac=cfg.mu / (1.0 + cfg.mu), norm=cfg.norm, eps=cfg.eps)
    if convention == "io":
        return W_new.T, jnp.swapaxes(V_new, 1, 2)
    return W_new, V_new


def _leaf_apply_sharded(alpha, ctx, cfg: MAEchoConfig, convention: str,
                        mesh):
    """Update half of the mesh-sharded pipeline: Eq. 7 + Eq. 11 run
    row-local on each device's owned shard — no collectives."""
    from repro.kernels import ops

    W_new, V_new = ops.maecho_sharded_apply(
        alpha, ctx, mesh=mesh, axis=cfg.mesh_axis, eta=cfg.eta,
        frac=cfg.mu / (1.0 + cfg.mu), norm=cfg.norm, eps=cfg.eps)
    if convention == "io":
        return W_new.T, jnp.swapaxes(V_new, 1, 2)
    return W_new, V_new


def _leaf_gram_stacked(W, V, P, cfg: MAEchoConfig, convention: str,
                       route: str, mesh, levels: int,
                       block: int = 0):
    """Gram half for a stacked leaf on the kernel or sharded
    pipelines: the ``levels`` leading layer axes are flattened into
    the kernel grid's outer dimension — ONE launch (and, sharded, ONE
    psum carrying the (L, N, N) stack) covers every scanned layer.
    ``route`` is the leaf plan's: "stacked" | "sharded" | "sharded2d".
    Returns ``(G, ctx)`` with G carrying the original leading axes
    before its trailing (N, N), matching the oracle-vmap layout."""
    from repro.kernels import ops

    Wf, Vf, Pf, lead = _flatten_stack(W, V, P, levels)
    Wk, Vk, Pk = _to_kernel_layout(Wf, Vf, Pf, convention, levels=1)
    if route == "sharded2d":
        G, ctx = ops.maecho_sharded2d_gram_stacked(
            Wk, Vk, Pk, mesh=mesh, axis_out=cfg.mesh_axis,
            axis_in=cfg.mesh_in_axis)
    elif route == "sharded":
        G, ctx = ops.maecho_sharded_gram_stacked(Wk, Vk, Pk, mesh=mesh,
                                                 axis=cfg.mesh_axis)
    else:
        G, ctx = ops.maecho_streaming_gram_stacked(
            Wk, Vk, Pk, block=block or ops.DEFAULT_BLOCK)
    return G.reshape(lead + G.shape[-2:]), ("stk", route, lead, ctx)


def _leaf_apply_stacked(alpha, ctx, cfg: MAEchoConfig,
                        convention: str, mesh, block: int = 0):
    """Update half for a stacked leaf: per-layer Eq. 7 + Eq. 11 from
    the flattened-grid context.  ``alpha`` carries the leaf's leading
    stack axes before its trailing N (the QP batch layout)."""
    from repro.kernels import ops

    _, route, lead, inner = ctx
    af = alpha.reshape((-1,) + alpha.shape[-1:])
    kw = dict(eta=cfg.eta, frac=cfg.mu / (1.0 + cfg.mu), norm=cfg.norm,
              eps=cfg.eps)
    if route == "sharded2d":
        Wn, Vn = ops.maecho_sharded2d_apply_stacked(
            af, inner, mesh=mesh, axis_out=cfg.mesh_axis,
            axis_in=cfg.mesh_in_axis, **kw)
    elif route == "sharded":
        Wn, Vn = ops.maecho_sharded_apply_stacked(
            af, inner, mesh=mesh, axis=cfg.mesh_axis, **kw)
    else:
        Wn, Vn = ops.maecho_streaming_apply_stacked(
            af, inner, block=block or ops.DEFAULT_BLOCK, **kw)
    if convention == "io":
        Wn, Vn = jnp.swapaxes(Wn, -1, -2), jnp.swapaxes(Vn, -1, -2)
    return (Wn.reshape(lead + Wn.shape[-2:]),
            Vn.reshape(Vn.shape[:1] + lead + Vn.shape[-2:]))


def _leaf_gram_chunked(W, V, P, lp: LeafPlan, cfg: MAEchoConfig,
                       convention: str, mesh):
    """Gram half for a leaf with a compiled ``client_chunk``: the
    (N, N) Gram accumulates over blocks of clients, so peak residual
    residency is O(chunk), not O(N) — the cross-device large-N mode.
    The chunk sweep composes with the leaf's route: "kernel" streams
    each (chunk, chunk) pair block through the Pallas cross-Gram,
    "sharded" additionally splits out-rows over ``cfg.mesh_axis``
    (still ONE psum per leaf per iteration), everything else — the
    oracle and the sub-tile shapes — runs the jnp chunk sweep."""
    from repro.kernels import ops

    chunk = lp.client_chunk
    if lp.levels > 0:
        Wf, Vf, Pf, lead = _flatten_stack(W, V, P, lp.levels)
        Wk, Vk, Pk = _to_kernel_layout(Wf, Vf, Pf, convention, levels=1)
        if lp.route == "sharded":
            G, ctx = ops.maecho_sharded_gram_chunked(
                Wk, Vk, Pk, mesh=mesh, axis=cfg.mesh_axis, chunk=chunk,
                stacked=True)
        else:
            G, ctx = ops.maecho_streaming_gram_chunked_stacked(
                Wk, Vk, Pk, chunk=chunk)
        return (G.reshape(lead + G.shape[-2:]),
                ("stkchunk", lp.route, lead, ctx))
    Wk, Vk, Pk = _to_kernel_layout(W, V, P, convention)
    if lp.route == "sharded":
        G, ctx = ops.maecho_sharded_gram_chunked(
            Wk, Vk, Pk, mesh=mesh, axis=cfg.mesh_axis, chunk=chunk)
    else:
        G, ctx = ops.maecho_streaming_gram_chunked(
            Wk, Vk, Pk, chunk=chunk,
            use_kernel=(lp.route == "kernel"))
    return G, ("chunkroute", lp.route, ctx)


def _leaf_apply_chunked(alpha, ctx, cfg: MAEchoConfig, convention: str,
                        mesh):
    """Update half for a chunked leaf: Eq. 7 accumulates over chunk
    residuals, Eq. 11 rebuilds each chunk's anchors — the full-N
    residual never materializes."""
    from repro.kernels import ops

    kw = dict(eta=cfg.eta, frac=cfg.mu / (1.0 + cfg.mu), norm=cfg.norm,
              eps=cfg.eps)
    if ctx[0] == "stkchunk":
        _, route, lead, inner = ctx
        af = alpha.reshape((-1,) + alpha.shape[-1:])
        if route == "sharded":
            Wn, Vn = ops.maecho_sharded_apply_chunked(
                af, inner, mesh=mesh, axis=cfg.mesh_axis, stacked=True,
                **kw)
        else:
            Wn, Vn = ops.maecho_streaming_apply_chunked_stacked(
                af, inner, **kw)
        if convention == "io":
            Wn, Vn = jnp.swapaxes(Wn, -1, -2), jnp.swapaxes(Vn, -1, -2)
        return (Wn.reshape(lead + Wn.shape[-2:]),
                Vn.reshape(Vn.shape[:1] + lead + Vn.shape[-2:]))
    _, route, inner = ctx
    if route == "sharded":
        Wn, Vn = ops.maecho_sharded_apply_chunked(
            alpha, inner, mesh=mesh, axis=cfg.mesh_axis, **kw)
    else:
        Wn, Vn = ops.maecho_streaming_apply_chunked(alpha, inner, **kw)
    if convention == "io":
        return Wn.T, jnp.swapaxes(Vn, 1, 2)
    return Wn, Vn


def _leaf_gram_oracle(W, V, P, convention: str):
    """Reference gram half: materializes the residual once and returns
    it as the reuse context for :func:`_leaf_apply_oracle` (the same
    tensor the fused step shared between its Gram and Eq. 7)."""
    N = V.shape[0]
    R = jax.vmap(lambda v, p: _apply_P(W - v, p, convention))(V, P)  # (N, ...)
    Rf = R.reshape(N, -1).astype(jnp.float32)
    return Rf @ Rf.T, R                                            # (N, N)


def _leaf_apply_oracle(W, V, P, R, alpha, cfg: MAEchoConfig,
                       convention: str):
    """Reference update half: Eq. 7 from the cached residual, then the
    Eq. 11 anchor update."""
    D = -2.0 * jnp.tensordot(alpha, R.astype(jnp.float32), axes=(0, 0))
    W_new = (W.astype(jnp.float32) + cfg.eta * D).astype(W.dtype)

    # Eq. 11: V_i += Norm((W' − V_i)(I − μ/(1+μ) P_i))
    frac = cfg.mu / (1.0 + cfg.mu)

    def v_update(v, p):
        delta = W_new - v
        U = delta - frac * _apply_P(delta, p, convention)
        if cfg.norm:
            ax = -1 if convention == "oi" else 0
            nrm = jnp.linalg.norm(
                U.astype(jnp.float32), axis=ax, keepdims=True)
            U = U / jnp.maximum(nrm, cfg.eps).astype(U.dtype)
        return v + U

    V_new = jax.vmap(v_update)(V, P)
    return W_new, V_new


# --------------------------------------------------------------------------
# the executor: per-leaf gram/apply keyed purely off the compiled plan
# --------------------------------------------------------------------------
def _leaf_gram(W, V, P, lp: LeafPlan, cfg: MAEchoConfig,
               convention: str, mesh=None):
    """Gram phase for one leaf, dispatched on its compiled
    ``LeafPlan.route`` — no shape inspection happens here, the plan is
    the single source of truth.

    Returns ``(G, ctx)``: G carries any stacked-layer axes in front of
    its trailing (N, N) — the batched caller flattens those into the
    QP batch axis — and ``ctx`` is the per-leaf reuse payload for
    :func:`_leaf_apply` (the oracle residual, or the kernel/sharded
    pipelines' padded-operand context)."""
    if lp.client_chunk:
        return _leaf_gram_chunked(W, V, P, lp, cfg, convention, mesh)
    route = lp.route
    if route == "oracle":
        if lp.levels > 0:
            # any number of leading stacked-layer axes collapses to
            # ONE flat scan axis before a single vmap (nested vmaps
            # over the oracle trip XLA:CPU's simplifier on dense
            # projector contractions); maecho_aggregate pre-flattens
            # multi-level stacks, but direct executor callers (the
            # dryrun driver) hand levels >= 2 leaves straight in
            Wf, Vf, Pf, lead = _flatten_stack(W, V, P, lp.levels)
            G, R = jax.vmap(
                lambda w, v, p: _leaf_gram_oracle(w, v, p, convention),
                in_axes=(0, 1, 1), out_axes=0)(Wf, Vf, Pf)
            return G.reshape(lead + G.shape[1:]), R
        return _leaf_gram_oracle(W, V, P, convention)
    if lp.levels > 0:
        return _leaf_gram_stacked(W, V, P, cfg, convention, route,
                                  mesh, lp.levels, lp.block)
    if route == "sharded2d":
        return _leaf_gram_sharded2d(W, V, P, cfg, convention, mesh)
    if route == "sharded":
        return _leaf_gram_sharded(W, V, P, cfg, convention, mesh)
    return _leaf_gram_kernel(W, V, P, cfg, convention, lp.block)


def _leaf_apply(W, V, P, ctx, alpha, lp: LeafPlan, cfg: MAEchoConfig,
                convention: str, mesh=None):
    """Apply phase for one leaf: scatter its rows of the stacked solve
    back through Eq. 7 / Eq. 11 on the route the plan compiled.
    ``alpha`` carries the leaf's stacked-layer axes in front of its
    trailing N, mirroring the gram layout."""
    if lp.client_chunk:
        return _leaf_apply_chunked(alpha, ctx, cfg, convention, mesh)
    route = lp.route
    if route == "oracle":
        if lp.levels > 0:
            # ctx is the flat (L, N, ...) residual stack from
            # _leaf_gram; alpha carries the original lead axes
            Wf, Vf, Pf, lead = _flatten_stack(W, V, P, lp.levels)
            af = alpha.reshape((-1,) + alpha.shape[-1:])
            Wn, Vn = jax.vmap(
                lambda w, v, p, r, a: _leaf_apply_oracle(
                    w, v, p, r, a, cfg, convention),
                in_axes=(0, 1, 1, 0, 0), out_axes=(0, 1))(Wf, Vf, Pf,
                                                          ctx, af)
            return (Wn.reshape(lead + Wn.shape[1:]),
                    Vn.reshape(Vn.shape[:1] + lead + Vn.shape[2:]))
        return _leaf_apply_oracle(W, V, P, ctx, alpha, cfg, convention)
    if lp.levels > 0:
        return _leaf_apply_stacked(alpha, ctx, cfg, convention, mesh,
                                   lp.block)
    if route == "sharded2d":
        return _leaf_apply_sharded2d(alpha, ctx, cfg, convention, mesh)
    if route == "sharded":
        return _leaf_apply_sharded(alpha, ctx, cfg, convention, mesh)
    return _leaf_apply_kernel(alpha, ctx, cfg, convention, lp.block)


def _leaf_sequential(W, V, P, lp: LeafPlan, cfg: MAEchoConfig,
                     convention: str, mesh=None, mask=None):
    """One Algorithm-1 iteration for a single leaf on the sequential-QP
    path (``qp_batched=False``): gram → own PGD solve (per scanned
    layer for stacked leaves, matching the paper's per-layer loop) →
    apply.  The participation mask is shared by every scanned layer.
    Returns (W', V')."""
    G, ctx = _leaf_gram(W, V, P, lp, cfg, convention, mesh)
    if lp.levels > 0:
        Gf = G.reshape((-1,) + G.shape[-2:])
        alpha = jax.vmap(lambda g: _qp_alpha(g, cfg, mask))(Gf)
        alpha = alpha.reshape(G.shape[:-2] + alpha.shape[-1:])
    else:
        alpha = _qp_alpha(G, cfg, mask)
    return _leaf_apply(W, V, P, ctx, alpha, lp, cfg, convention, mesh)


# --------------------------------------------------------------------------
# full aggregation
# --------------------------------------------------------------------------
def default_projections(client_weights: list[Pytree]) -> list[Pytree]:
    """Scalar full projectors everywhere (degenerates MA-Echo toward a
    consensus pull; used when a leaf has no feature statistics)."""
    return [trees.tree_map(lambda x: jnp.ones((), x.dtype), w)
            for w in client_weights]


def init_global(client_weights: list[Pytree], how: str,
                rng: Optional[jax.Array] = None) -> Pytree:
    n = len(client_weights)
    if how == "average":
        out = client_weights[0]
        for w in client_weights[1:]:
            out = trees.tree_add(out, w)
        return trees.tree_scale(out, 1.0 / n)
    if how == "first":
        return trees.tree_map(lambda x: x, client_weights[0])
    if how == "random":
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        leaves, treedef = jax.tree_util.tree_flatten(client_weights[0])
        keys = jax.random.split(rng, len(leaves))
        new = [jax.random.normal(k, x.shape, x.dtype) *
               (jnp.std(x) + 1e-8) for k, x in zip(keys, leaves)]
        return jax.tree_util.tree_unflatten(treedef, new)
    raise ValueError(f"unknown init {how!r}")


@partial(jax.jit, static_argnames=("cfg", "convention", "plan",
                                   "mesh"))
def _maecho_jit(W0, V0, P, cfg: MAEchoConfig, convention: str,
                plan: AggPlan, mesh=None, masks=None):
    """The pure executor: runs the τ-loop over the COMPILED plan —
    every per-leaf decision was already frozen into ``plan.leaves``
    (one :class:`LeafPlan` per flattened leaf, same order), so the
    loop body below contains zero routing logic."""
    def outer(_, state):
        W, V = state
        flatW, treedef = jax.tree_util.tree_flatten(W)
        flatV = treedef.flatten_up_to(V)
        flatP = treedef.flatten_up_to(P)
        flatM = (list(masks) if masks is not None
                 else [None] * len(flatW))
        if cfg.qp_batched:
            # Phase 1: every leaf's (and every scanned layer's) Eq. 6
            # Gram, assembled into one (L, N, N) stack.  N — the
            # client count — is shared by construction inside one
            # aggregate call, so stack_grams degenerates to a pure
            # concat here (its padding serves the ragged case).
            grams, ctxs = [], []
            for w, v, p, lp in zip(flatW, flatV, flatP, plan.leaves):
                g, ctx = _leaf_gram(w, v, p, lp, cfg, convention, mesh)
                grams.append(g)
                ctxs.append(ctx)
            Gstack, n_valid = qp_mod.stack_grams(grams)
            # Phase 2: ONE vmapped PGD solve for the whole batch —
            # with ragged participation, each leaf's client mask
            # (broadcast over its scanned layers) rides the solver's
            # validity masking instead of the prefix n_valid.
            if masks is None:
                alphas = qp_mod.solve_qp_batched(
                    Gstack, cfg.C, cfg.qp_iters, n_valid,
                    row_block=cfg.client_chunk)
            else:
                rows = [jnp.broadcast_to(m, (math.prod(g.shape[:-2]),)
                                         + m.shape)
                        for g, m in zip(grams, flatM)]
                alphas = qp_mod.solve_qp_batched(
                    Gstack, cfg.C, cfg.qp_iters,
                    mask=jnp.concatenate(rows, 0),
                    row_block=cfg.client_chunk)
            # Phase 3: … scattered back through each leaf's Eq. 7/11.
            out, ofs = [], 0
            for w, v, p, lp, ctx, g in zip(flatW, flatV, flatP,
                                           plan.leaves, ctxs, grams):
                cnt = math.prod(g.shape[:-2])
                a = alphas[ofs:ofs + cnt].reshape(
                    g.shape[:-2] + alphas.shape[-1:])
                ofs += cnt
                out.append(_leaf_apply(w, v, p, ctx, a, lp, cfg,
                                       convention, mesh))
        else:
            out = [_leaf_sequential(w, v, p, lp, cfg, convention,
                                    mesh, m)
                   for w, v, p, lp, m in zip(flatW, flatV, flatP,
                                             plan.leaves, flatM)]
        if masks is not None:
            # non-participants contribute nothing (α = 0 via the QP
            # mask) and their anchors stay put — the run matches
            # aggregating the participating subset alone
            out = [(w2, jnp.where(
                        m.reshape((-1,) + (1,) * (v1.ndim - 1)),
                        v2, v1))
                   for (w2, v2), v1, m in zip(out, flatV, flatM)]
        W = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        V = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return W, V

    if cfg.tau <= 4:
        # unrolled (also gives the roofline probe loop-free HLO)
        state = (W0, V0)
        for t in range(cfg.tau):
            state = outer(t, state)
        return state
    W, V = jax.lax.fori_loop(0, cfg.tau, outer, (W0, V0))
    return W, V


def dispatch_summary(W0: Pytree, P: Pytree, levels_tree: Pytree,
                     cfg: MAEchoConfig = MAEchoConfig(),
                     convention: str = "oi", backend: str = "oracle",
                     mesh=None):
    """Per-leaf compute-path report: a VIEW over the compiled
    :class:`AggPlan` — the same object the executor dispatches on, so
    the route reported here is definitionally the route that runs
    (the pre-plan implementation maintained a second copy of the
    routing rules, which could drift).

    ``W0`` / ``P`` are the global-weight and *stacked* (leading client
    axis) projector trees — arrays or ``jax.ShapeDtypeStruct``s both
    work, routing is static-shape-only.  Returns ``(per_leaf,
    counts)``: ``per_leaf`` is a list of ``(path, levels, route)``
    with route in ``plan.ROUTES`` ({"oracle", "kernel", "stacked",
    "sharded", "sharded2d"}); ``counts`` maps route -> leaf count,
    plus a ``"chunked"`` entry (the number of leaves sweeping their
    client axis in ``cfg.client_chunk`` blocks) whenever chunking is
    active.
    """
    plan = compile_plan(W0, P, levels_tree, cfg, convention, backend,
                        mesh)
    counts = plan.route_counts()
    chunked = sum(1 for lp in plan.leaves if lp.client_chunk)
    if chunked:
        counts["chunked"] = chunked
    return plan.per_leaf(), counts


def _default_mesh(axis_name: str, in_axis_name: Optional[str] = None):
    """Mesh over every visible device — the sharded backends'
    convenience default, so ``maecho_backend="sharded"`` works without
    explicit mesh plumbing (pass a real mesh for production).  With
    ``in_axis_name`` (the ``"sharded2d"`` default) the mesh carries a
    trivial size-1 in-axis: all devices stay on the out-row axis, and
    callers that want real 2-D spans pass their own factored mesh."""
    import numpy as np
    from jax.sharding import Mesh

    devs = np.asarray(jax.devices())
    if in_axis_name is None:
        return Mesh(devs, (axis_name,))
    return Mesh(devs.reshape(len(devs), 1),
                (axis_name, in_axis_name))


def _normalize_client_mask(client_mask, W0, n_clients: int):
    """Per-leaf (N,) boolean masks, aligned with ``tree_flatten(W0)``.

    Accepts one (N,) mask (applies to every leaf) or a pytree matching
    the weight structure whose leaves are (N,) masks."""
    if (hasattr(client_mask, "ndim")
            or (isinstance(client_mask, (list, tuple))
                and not any(isinstance(x, (list, tuple, dict))
                            for x in client_mask))):
        m = jnp.asarray(client_mask, bool)
        mask_tree = trees.tree_map(lambda _: m, W0)
    else:
        mask_tree = jax.tree_util.tree_map(
            lambda x: jnp.asarray(x, bool), client_mask)
    treedef = jax.tree_util.tree_structure(W0)
    masks = tuple(treedef.flatten_up_to(mask_tree))
    for m in masks:
        if m.shape != (n_clients,):
            raise ValueError(
                f"client_mask leaves must be ({n_clients},) booleans, "
                f"got shape {m.shape}")
        # concrete here (outside jit): an all-False leaf would make
        # the Σα = 1 constraint unsatisfiable and silently return the
        # init point — surface the upstream participation bug instead
        if not bool(m.any()):
            raise ValueError(
                "client_mask excludes every client for some leaf — "
                "at least one participant is required")
    return masks


def maecho_aggregate(
    client_weights: list[Pytree],
    projections: Optional[list[Pytree]] = None,
    cfg: MAEchoConfig = MAEchoConfig(),
    convention: str = "oi",
    init_point: Optional[Pytree] = None,
    rng: Optional[jax.Array] = None,
    stack_levels=None,
    return_anchors: bool = False,
    backend: str = "oracle",
    mesh=None,
    client_mask=None,
):
    """Run Algorithm 1.  Returns the global model pytree.

    client_weights: list over clients of structurally identical pytrees.
    projections:    matching list of projector pytrees (see module doc);
                    ``None`` falls back to scalar full projectors.
    stack_levels:   per-leaf count of leading stacked-layer axes —
                    ``None`` (all 0, the paper's MLP/CNN layout), a
                    pytree of ints matching the weights, or a callable
                    ``path -> int`` (the LLM scan-over-layers layout).
                    Stacked leaves are first-class on every backend:
                    eligible ones fold their (flattened) layer axis
                    into the kernel grid; projector leaves must carry
                    the same leading axes.
    backend:        ``"oracle"`` | ``"kernel"`` | ``"auto"`` |
                    ``"sharded"`` | ``"sharded2d"`` — the jnp
                    reference path, the fused streaming Pallas
                    pipeline, its out-dim mesh-sharded form, or the
                    2-D (out × in) multi-axis shard (module
                    docstring).  Unknown strings raise with the full
                    choice list.
    mesh:           ``jax.sharding.Mesh`` carrying ``cfg.mesh_axis``
                    for ``backend="sharded"`` (default: a 1-D mesh
                    over every visible device) — plus
                    ``cfg.mesh_in_axis`` for ``backend="sharded2d"``
                    (default: all devices on the out-row axis and a
                    trivial size-1 in-axis).  Ignored otherwise.
    client_mask:    optional ragged-participation mask — one (N,)
                    boolean vector, or a pytree of them matching the
                    weight structure (per-leaf client subsets).
                    Masked-out clients get exactly α = 0, their
                    anchors are frozen, and the result matches
                    aggregating the subset alone.  At least one client
                    must be masked in per leaf.
    """
    plan_mod.validate_backend(backend)
    if backend == "sharded" and mesh is None:
        mesh = _default_mesh(cfg.mesh_axis)
    if backend == "sharded2d" and mesh is None:
        mesh = _default_mesh(cfg.mesh_axis, cfg.mesh_in_axis)
    if backend not in ("sharded", "sharded2d"):
        mesh = None                 # keep the jit cache key canonical
    if projections is None:
        projections = default_projections(client_weights)
    W0 = (init_point if init_point is not None
          else init_global(client_weights, cfg.init, rng))
    masks = (None if client_mask is None else
             _normalize_client_mask(client_mask, W0,
                                    len(client_weights)))
    if stack_levels is None:
        levels_tree = trees.tree_map(lambda _: 0, W0)
    elif callable(stack_levels):
        levels_tree = trees.map_with_path(
            lambda path, _: stack_levels(path), W0)
    else:
        levels_tree = stack_levels
    levels = tuple(jax.tree_util.tree_leaves(levels_tree))
    V0 = trees.tree_map(lambda *xs: jnp.stack(xs, 0), *client_weights)
    P = trees.tree_map(lambda *xs: jnp.stack(xs, 0), *projections)
    # Multi-level stacks collapse to ONE flat scan axis before dispatch
    # (pure reshape — the QP treats every scanned layer independently,
    # so per-layer semantics are unchanged): the stacked kernel grid
    # wants a single layer axis, and nested vmaps over the oracle both
    # cost an extra batch dim and trip XLA:CPU's simplifier on dense
    # projector contractions.  Outputs are reshaped back below.
    treedef = jax.tree_util.tree_structure(W0)
    multi = any(lv > 1 for lv in levels)
    if multi:
        leads = tuple(w.shape[:lv] for w, lv in
                      zip(jax.tree_util.tree_leaves(W0), levels))
        fW, fV, fP = [], [], []
        for w, v, p, lv in zip(jax.tree_util.tree_leaves(W0),
                               treedef.flatten_up_to(V0),
                               treedef.flatten_up_to(P), levels):
            if lv > 1:
                w, v, p, _ = _flatten_stack(w, v, p, lv)
            fW.append(w)
            fV.append(v)
            fP.append(p)
        W0 = jax.tree_util.tree_unflatten(treedef, fW)
        V0 = jax.tree_util.tree_unflatten(treedef, fV)
        P = jax.tree_util.tree_unflatten(treedef, fP)
    run_levels = tuple(min(lv, 1) for lv in levels) if multi else levels
    # the compile-once step: routing for every leaf is frozen here
    # (memoized — repeated aggregations over the same model reuse the
    # identical plan object AND therefore the executor's jit cache)
    plan = compile_plan(
        W0, P, jax.tree_util.tree_unflatten(treedef, list(run_levels)),
        cfg, convention, backend, mesh)
    W, V = _maecho_jit(W0, V0, P, cfg, convention, plan, mesh, masks)
    if multi:
        W = jax.tree_util.tree_unflatten(treedef, [
            w.reshape(lead + w.shape[1:]) if lv > 1 else w
            for w, lead, lv in zip(jax.tree_util.tree_leaves(W),
                                   leads, levels)])
        V = jax.tree_util.tree_unflatten(treedef, [
            v.reshape(v.shape[:1] + lead + v.shape[2:]) if lv > 1 else v
            for v, lead, lv in zip(treedef.flatten_up_to(V),
                                   leads, levels)])
    return (W, V) if return_anchors else W
