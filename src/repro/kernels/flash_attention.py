"""Pallas TPU kernel: causal flash attention with GQA.

The model zoo's training/prefill hot spot.  Online-softmax over KV
blocks with running (m, l, acc) in VMEM scratch; grid
(batch, q_heads, n_q_blocks, n_kv_blocks) with scratch carried across
the innermost axis.  Oracle: ``repro.models.layers.chunked_attention``
(pure jnp, same math) — swept in tests/test_kernels.py.

Blocks: q (bq, d), k/v (bk, d); MXU-aligned when bq, bk, d are
multiples of 128 (head_dim 64/80/96 still lower, at reduced MXU
utilisation — noted in the roofline).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.env import interpret_default

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, bq: int, bk: int, n_k: int, causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip fully-masked blocks (causal: kv block strictly after q block)
    run = True
    if causal:
        run = (ki * bk) <= (qi * bq + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[...].astype(jnp.float32)            # (bq, d)
        k = k_ref[...].astype(jnp.float32)            # (bk, d)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot(q, k.T,
                        preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot(p, v,
                                      preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...][:, None], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 256,
                    bk: int = 256, interpret: bool | None = None):
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D); returns (B, Sq, Hq, D).

    GQA is handled by an index_map trick: kv head = q head // group.
    Sequences must be multiples of the block sizes (caller pads).
    ``interpret=None`` resolves through ``REPRO_PALLAS_INTERPRET`` like
    every other kernel (a bare default of True would silently pin the
    raw entry point to the interpreter even on a TPU launch).
    """
    if interpret is None:
        interpret = interpret_default()
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    group = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    n_q, n_k = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(D)

    qt = q.transpose(0, 2, 1, 3)      # (B, Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3)      # (B, Hkv, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, scale=scale, bq=bq, bk=bk,
                               n_k=n_k, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, None, bq, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((None, None, bk, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((None, None, bk, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
