"""Non-IID client partitions (paper §7, Figure 2).

``dirichlet_partition``: p_c ~ Dir(β·1_K); allocate a p_{c,k} fraction
of each class-c sample set to client k — β→0 gives disjoint label
support (the paper's extreme non-identical setting), β→∞ gives IID.

``label_shard_partition``: each client gets exactly ``n_labels``
classes (the multi-round FL setting, §7.4 "#Class = 2").
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, beta: float,
                        seed: int = 0, min_size: int = 2) -> list[np.ndarray]:
    rng = np.random.RandomState(seed)
    K = int(labels.max()) + 1
    for _ in range(100):
        idx_per_client: list[list[int]] = [[] for _ in range(n_clients)]
        for c in range(K):
            idx_c = np.where(labels == c)[0]
            rng.shuffle(idx_c)
            p = rng.dirichlet([beta] * n_clients)
            cuts = (np.cumsum(p) * len(idx_c)).astype(int)[:-1]
            for k, part in enumerate(np.split(idx_c, cuts)):
                idx_per_client[k].extend(part.tolist())
        sizes = [len(ix) for ix in idx_per_client]
        if min(sizes) >= min_size:
            break
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in idx_per_client]


def label_shard_partition(labels: np.ndarray, n_clients: int,
                          n_labels: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.RandomState(seed)
    K = int(labels.max()) + 1
    client_classes = [rng.choice(K, size=n_labels, replace=False)
                      for _ in range(n_clients)]
    out = []
    for k in range(n_clients):
        mask = np.isin(labels, client_classes[k])
        idx = np.where(mask)[0]
        # split class data among the clients that hold it
        holders = [j for j in range(n_clients)
                   if np.intersect1d(client_classes[j],
                                     client_classes[k]).size]
        rng_k = np.random.RandomState(seed + 17 * k)
        keep = rng_k.rand(len(idx)) < 1.0 / max(1, len(holders) / 2)
        out.append(idx[keep])
    return out


def partition_stats(labels: np.ndarray, parts: list[np.ndarray]) -> str:
    K = int(labels.max()) + 1
    lines = []
    for k, ix in enumerate(parts):
        hist = np.bincount(labels[ix], minlength=K)
        lines.append(f"client {k}: n={len(ix):6d} " +
                     " ".join(f"{h:5d}" for h in hist))
    return "\n".join(lines)
