"""The paper's MNIST MLP (784 -> 400 -> 200 -> 100 -> 10), §7."""
from repro.fl.models import MLP_SPEC, PaperModelSpec


def config() -> PaperModelSpec:
    return MLP_SPEC


def smoke_config() -> PaperModelSpec:
    import dataclasses
    return dataclasses.replace(MLP_SPEC, in_shape=(64,), hidden=(32, 16))
