"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture × input shape) step for the
production mesh — 16×16 single-pod and 2×16×16 multi-pod — and records
memory / cost / collective analysis for the roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--out experiments/dryrun]
"""
# The XLA flag MUST precede any jax import: jax locks the device count
# at first initialisation.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import assigned_archs, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.config import INPUT_SHAPES  # noqa: E402
from repro.models.zoo import get_model  # noqa: E402
from repro.optim import sgd  # noqa: E402
from repro.roofline import analysis as rl  # noqa: E402
from repro.roofline import memmodel  # noqa: E402
from repro.roofline import probe as rlp  # noqa: E402
from repro.sharding import ctx as shard_ctx  # noqa: E402
from repro.sharding.rules import make_rules, data_axes  # noqa: E402
from repro.utils import trees  # noqa: E402

# long-context policy (DESIGN.md §5): SSM/hybrid run long_500k natively;
# attention archs use the sliding-window ring buffer — implemented for
# all, so no arch skips the shape.
SKIPS: dict[tuple, str] = {}


def _moe_gather(cfg):
    import dataclasses
    return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                               dispatch_mode="gather"))


# §Perf variants: config transforms measured against the baseline
VARIANTS = {
    "moe-gather": _moe_gather,
    "no-seq-shard": lambda cfg: cfg.replace(seq_shard=False),
    "seq-shard": lambda cfg: cfg.replace(seq_shard=True),
    "mb8": lambda cfg: cfg.replace(microbatches=8),
    "mb32": lambda cfg: cfg.replace(microbatches=32),
    "ctxfix": lambda cfg: cfg,          # identity: re-measure with the
                                        # sharding-constraint code paths
    "noss-mb32": lambda cfg: cfg.replace(seq_shard=False,
                                         microbatches=32),
    "group8k": lambda cfg: _group(cfg, 8192),
    "group2k": lambda cfg: _group(cfg, 2048),
    "win4k": lambda cfg: cfg.replace(window=4096),
    "chunkq1k": lambda cfg: cfg.replace(attn_chunk_q=1024),
}


def _group(cfg, g):
    import dataclasses
    return cfg.replace(moe=dataclasses.replace(cfg.moe, group_size=g))


def _replicated(mesh, tree):
    return trees.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def build_step(arch: str, shape_name: str, mesh, cfg=None, shape=None):
    """Returns (step_fn, example_args (ShapeDtypeStructs), in_shardings,
    step_kind).  ``cfg``/``shape`` overrides serve the roofline probe."""
    cfg = cfg or get_config(arch)
    shape = shape or INPUT_SHAPES[shape_name]
    model = get_model(cfg)
    rules = make_rules(mesh, cfg)

    pspecs = model.param_specs()
    param_sh = rules.params_shardings(pspecs)

    if shape.kind == "train":
        opt = sgd(lr=0.01, momentum=0.5, state_dtype=jnp.bfloat16)
        opt_specs = jax.eval_shape(opt.init, pspecs)
        opt_sh = trees.tree_map(
            lambda _: None, opt_specs) if not opt_specs else {
            "m": param_sh}
        base_step = model.make_train_step(opt)

        def step_fn(params, opt_state, batch, step):
            with shard_ctx.use_rules(rules):
                return base_step(params, opt_state, batch, step)

        inputs = model.input_specs(shape)
        input_sh = rules.inputs_shardings(inputs)
        if cfg.seq_shard:
            # context-parallel activations: shard seq over the model axis
            da = data_axes(mesh)
            for key in ("tokens", "labels"):
                if key in inputs:
                    input_sh[key] = NamedSharding(
                        mesh, P(da, "model"))
        args = (pspecs, opt_specs, inputs, jnp.int32(0))
        shardings = (param_sh, opt_sh, input_sh,
                     NamedSharding(mesh, P()))
        return step_fn, args, shardings, "train"

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return model.prefill(params, batch)
        inputs = model.input_specs(shape)
        input_sh = rules.inputs_shardings(inputs)
        return prefill_fn, (pspecs, inputs), (param_sh, input_sh), \
            "prefill"

    # decode
    serve = model.make_serve_step()

    def serve_with_ctx(params, cache, token, position):
        # pin cache shardings during tracing (§Perf H2)
        with shard_ctx.use_rules(rules):
            return serve(params, cache, token, position)

    inputs = model.input_specs(shape)
    cache_specs = inputs["cache"]
    input_sh = rules.inputs_shardings(inputs)
    args = (pspecs, cache_specs, inputs["token"], inputs["position"])
    shardings = (param_sh, input_sh["cache"], input_sh["token"],
                 NamedSharding(mesh, P()))
    return serve_with_ctx, args, shardings, "decode"


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            out_dir: str, variant: str = "") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if variant:
        cfg = VARIANTS[variant](cfg)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "status": "ok"}
    try:
        step_fn, args, shardings, kind = build_step(arch, shape_name,
                                                    mesh, cfg=cfg)
        with mesh:
            jitted = jax.jit(step_fn, in_shardings=shardings)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        coll = rl.collective_bytes(hlo)
        chips = mesh.devices.size

        # loop-free probe lowerings for exact per-layer HLO costs
        # (cost_analysis counts while bodies once — see roofline.probe)
        def probe_build(pcfg, pshape):
            # pcfg derives from the (already variant-transformed) cfg
            fn, a, sh, _ = build_step(arch, shape_name, mesh,
                                      cfg=pcfg, shape=pshape)
            with mesh:
                return jax.jit(fn, in_shardings=sh).lower(*a).compile()

        n_data_total = chips // 16    # data(16) x optional pod
        probe = rlp.probe_costs(probe_build, cfg, shape,
                                min_batch=n_data_total)
        roof = rl.Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name,
            flops_per_chip=probe["flops"],
            bytes_per_chip=probe["bytes"],
            coll_bytes_per_chip=probe["coll"] / chips,
            bytes_model_per_chip=memmodel.hbm_bytes(cfg, shape, kind,
                                                    mesh_name),
            model_flops=rl.model_flops(cfg, shape, kind), chips=chips)
        rec.update({
            "kind": kind,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes",
                                          None),
                "output_bytes": getattr(mem, "output_size_in_bytes",
                                        None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            "cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "transcendentals")
                     if k in cost},
            "collectives": coll,
            "probe": {k: v for k, v in probe.items()},
            "roofline": roof.to_dict(),
        })
        print(f"[ok] {arch:18s} {shape_name:12s} {mesh_name:8s} "
              f"lower {t_lower:6.1f}s compile {t_compile:6.1f}s "
              f"bottleneck={roof.bottleneck}")
    except Exception as e:  # noqa: BLE001 — record and continue
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        print(f"[FAIL] {arch} {shape_name} {mesh_name}: "
              f"{type(e).__name__}: {e}")
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{variant}" if variant else ""
    fn = os.path.join(out_dir,
                      f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="", choices=[""] +
                    list(VARIANTS))
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = assigned_archs() if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]

    n_fail = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_one(arch, shape, multi_pod=mp,
                              out_dir=args.out, variant=args.variant)
                n_fail += rec["status"] != "ok"
    print(f"done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
