"""The paper's experiment models: MLP, CNN, CVAE decoder.

Exactly the shapes used in MA-Echo's experiments:
  - MLP 784 -> 400 -> 200 -> 100 -> 10 (MNIST, §7)
  - CNN: three conv layers + three fully-connected layers (CIFAR-10)
  - CVAE decoder 30 -> 256 -> 512 -> 784 (§7.1, Figure 4)

These are the units MA-Echo aggregates.  Layers are kept as explicit
(W, b) pairs because the algorithm is layer-wise: ``layer_weights``
yields the 2-D matrices (conv kernels reshaped to out×(in·h·w), as in
the paper §5.2) together with their input-feature extractors used for
projection-matrix estimation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PaperModelSpec:
    name: str
    kind: str                      # mlp | cnn | cvae
    in_shape: tuple
    n_classes: int = 10
    hidden: tuple = (400, 200, 100)
    conv_channels: tuple = (32, 64, 64)
    fc_hidden: tuple = (256, 128)
    latent: int = 30
    cvae_hidden: tuple = (256, 512)


MLP_SPEC = PaperModelSpec("paper-mlp", "mlp", (784,))
CNN_SPEC = PaperModelSpec("paper-cnn", "cnn", (32, 32, 3))
CVAE_SPEC = PaperModelSpec("paper-cvae", "cvae", (794,))  # latent 30 + y 10 -> 784


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def mlp_init(spec: PaperModelSpec, rng):
    dims = (spec.in_shape[0],) + spec.hidden + (spec.n_classes,)
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        rng, k = jax.random.split(rng)
        W = jax.random.normal(k, (b, a)) * jnp.sqrt(2.0 / a)
        params.append({"W": W, "b": jnp.zeros((b,))})
    return params


def mlp_forward(params, x, *, return_features: bool = False):
    """x: (B, 784).  Returns logits (and per-layer input features)."""
    feats = []
    h = x
    for i, lay in enumerate(params):
        feats.append(h)
        h = h @ lay["W"].T + lay["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return (h, feats) if return_features else h


# --------------------------------------------------------------------------
# CNN (3 conv + 3 fc, CIFAR-10 shaped)
# --------------------------------------------------------------------------
def cnn_init(spec: PaperModelSpec, rng):
    H, W, Cin = spec.in_shape
    params = []
    c_prev = Cin
    for c in spec.conv_channels:
        rng, k = jax.random.split(rng)
        params.append({
            "W": jax.random.normal(k, (c, c_prev, 3, 3)) *
            jnp.sqrt(2.0 / (c_prev * 9)),
            "b": jnp.zeros((c,)),
        })
        c_prev = c
    # after three stride-2 3x3 convs: H/8 x W/8 x c
    flat = (H // 8) * (W // 8) * c_prev
    dims = (flat,) + spec.fc_hidden + (spec.n_classes,)
    for a, b in zip(dims[:-1], dims[1:]):
        rng, k = jax.random.split(rng)
        params.append({
            "W": jax.random.normal(k, (b, a)) * jnp.sqrt(2.0 / a),
            "b": jnp.zeros((b,)),
        })
    return params


def _conv2d(x, W, b, stride=2):
    # x: (B, H, W, C); W: (Cout, Cin, kh, kw)
    y = jax.lax.conv_general_dilated(
        x, W.transpose(2, 3, 1, 0), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def cnn_forward(params, x, *, return_features: bool = False):
    """x: (B, H, W, C)."""
    feats = []
    h = x
    i = 0
    for lay in params:
        if lay["W"].ndim == 4:
            # feature for projection: im2col patches (B*h*w, Cin*9)
            feats.append(_im2col(h, 3))
            h = jax.nn.relu(_conv2d(h, lay["W"], lay["b"]))
        else:
            if h.ndim == 4:
                h = h.reshape(h.shape[0], -1)
            feats.append(h)
            h = h @ lay["W"].T + lay["b"]
            i += 1
            if i < 3:
                h = jax.nn.relu(h)
    return (h, feats) if return_features else h


def _im2col(x, k):
    """Extract kxk patches with stride 2, SAME padding -> (N, C*k*k)."""
    B, H, W, C = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    rows = []
    for di in range(k):
        for dj in range(k):
            rows.append(xp[:, di:di + H:2, dj:dj + W:2, :])
    patches = jnp.stack(rows, axis=-1)         # (B, H/2, W/2, C, k*k)
    return patches.reshape(-1, C * k * k)


# --------------------------------------------------------------------------
# CVAE (decoder is the aggregated part; encoder used for local training)
# --------------------------------------------------------------------------
def cvae_init(spec: PaperModelSpec, rng):
    ks = jax.random.split(rng, 8)
    d_in = 784 + spec.n_classes

    def lin(k, a, b):
        return {"W": jax.random.normal(k, (b, a)) * jnp.sqrt(2.0 / a),
                "b": jnp.zeros((b,))}

    return {
        "enc": [lin(ks[0], d_in, 512), lin(ks[1], 512, 256)],
        "mu": lin(ks[2], 256, spec.latent),
        "logvar": lin(ks[3], 256, spec.latent),
        "dec": [lin(ks[4], spec.latent + spec.n_classes, 256),
                lin(ks[5], 256, 512), lin(ks[6], 512, 784)],
    }


def cvae_decode(dec_params, z, y_onehot, *, return_features: bool = False):
    h = jnp.concatenate([z, y_onehot], axis=-1)
    feats = []
    for i, lay in enumerate(dec_params):
        feats.append(h)
        h = h @ lay["W"].T + lay["b"]
        if i < len(dec_params) - 1:
            h = jax.nn.relu(h)
    h = jax.nn.sigmoid(h)
    return (h, feats) if return_features else h


def cvae_elbo(params, x, y_onehot, rng):
    h = jnp.concatenate([x, y_onehot], axis=-1)
    for lay in params["enc"]:
        h = jax.nn.relu(h @ lay["W"].T + lay["b"])
    mu = h @ params["mu"]["W"].T + params["mu"]["b"]
    logvar = h @ params["logvar"]["W"].T + params["logvar"]["b"]
    eps = jax.random.normal(rng, mu.shape)
    z = mu + jnp.exp(0.5 * logvar) * eps
    xhat = cvae_decode(params["dec"], z, y_onehot)
    rec = jnp.sum(jnp.square(x - xhat), axis=-1)
    kl = -0.5 * jnp.sum(1 + logvar - mu ** 2 - jnp.exp(logvar), axis=-1)
    return jnp.mean(rec + kl)


FORWARD: dict[str, Callable] = {
    "mlp": mlp_forward, "cnn": cnn_forward,
}

INIT: dict[str, Callable] = {
    "mlp": mlp_init, "cnn": cnn_init, "cvae": cvae_init,
}


def init(spec: PaperModelSpec, rng):
    return INIT[spec.kind](spec, rng)


def forward(spec: PaperModelSpec, params, x, **kw):
    return FORWARD[spec.kind](params, x, **kw)
