"""Process-wide Pallas interpret-mode switch.

``REPRO_PALLAS_INTERPRET`` (default "1": kernel bodies execute on CPU —
this container has no TPU) is read at trace time; a TPU launch flips the
one env var instead of editing call sites.  This lives in its own tiny
module so the raw kernel modules (``flash_attention``,
``decode_attention``) can resolve their ``interpret=None`` defaults
without importing ``ops`` — which imports them.
"""
from __future__ import annotations

import os

INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


def interpret_default() -> bool:
    """True unless REPRO_PALLAS_INTERPRET is 0/false/no/off."""
    val = os.environ.get(INTERPRET_ENV, "1").strip().lower()
    return val not in ("0", "false", "no", "off")
