"""Pallas TPU kernel: symmetric block-RLS projector downdate.

The OWM/block-RLS recursion (core/projections.py) is

    Q ← Q − U A Uᵀ,   U = Q X_bᵀ (d×b),   A = (αI_b + X_b Q X_bᵀ)⁻¹

The b×b inverse is trivial; the d×d downdate is the hot spot (d up to
16384 for the assigned archs ⇒ 256M-element update per block of
features).  This kernel fuses the rank-b symmetric downdate
``Q − U A Uᵀ`` over 128-aligned VMEM tiles: per output tile (i, j) it
keeps U_i (bo×b) and U_j·Aᵀ? — rather, computes U_i A U_jᵀ with A
staged in VMEM once, avoiding the d×b intermediate round-trip to HBM
that the naive three-GEMM chain costs.

GPU→TPU note (DESIGN.md §6): the original OWM uses n rank-1 updates
(vector ops, latency-bound on GPU warps); the block form converts the
recursion into MXU-shaped GEMM tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, u_ref, a_ref, uj_ref, out_ref):
    u_i = u_ref[...].astype(jnp.float32)          # (bo, b)
    a = a_ref[...].astype(jnp.float32)            # (b, b)
    u_j = uj_ref[...].astype(jnp.float32)         # (bj, b)
    upd = jax.lax.dot(jax.lax.dot(u_i, a,
                                  preferred_element_type=jnp.float32),
                      u_j.T, preferred_element_type=jnp.float32)
    out_ref[...] = (q_ref[...].astype(jnp.float32) - upd
                    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bo", "bj", "interpret"))
def rank_downdate(Q, U, A, *, bo: int = 256, bj: int = 256,
                  interpret: bool = True):
    """Q − U A Uᵀ.  Q: (d, d); U: (d, b); A: (b, b) small."""
    d, b = U.shape
    bo = min(bo, d)
    bj = min(bj, d)
    assert d % bo == 0 and d % bj == 0
    grid = (d // bo, d // bj)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bo, bj), lambda i, j: (i, j)),   # Q tile
            pl.BlockSpec((bo, b), lambda i, j: (i, 0)),    # U_i rows
            pl.BlockSpec((b, b), lambda i, j: (0, 0)),     # A (staged)
            pl.BlockSpec((bj, b), lambda i, j: (j, 0)),    # U_j rows
        ],
        out_specs=pl.BlockSpec((bo, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), Q.dtype),
        interpret=interpret,
    )(Q, U, A, U)


def block_rls_update(Q, Xb, alpha: float = 1.0, *, interpret: bool = True,
                     bo: int = 256):
    """One full block-RLS step using the fused downdate kernel.

    Equivalent to ``repro.core.projections.block_update`` (the oracle).
    """
    QX = Q @ Xb.T                                  # (d, b) — plain GEMM
    S = alpha * jnp.eye(Xb.shape[0], dtype=Q.dtype) + Xb @ QX
    A = jnp.linalg.inv(S)
    A = 0.5 * (A + A.T)
    return rank_downdate(Q, QX, A, bo=bo, bj=bo, interpret=interpret)
