"""Multi-round federated learning (paper §5.3 "Applied to Multi-round
Federated Learning" / §7.4).

Each communication round: sample m of N clients, local-train from the
global model, aggregate.  The aggregation operator is pluggable —
``fedavg``, ``fedprox`` (fedavg + proximal local loss), or ``maecho``
(Algorithm 1 replaces the averaging operation, the paper's claim that
it converges in fewer rounds).

The maecho round hands the sampled clients' *whole leaf batch* to one
aggregation call: with ``MAEchoConfig.qp_batched`` (default) every
outer iteration stacks all layers' Gram matrices and issues a single
vmapped PGD solve instead of one QP per layer — the round loop never
serialises over leaves.  ``MultiRoundConfig.maecho_backend`` selects
the per-leaf compute path (``"oracle"`` | ``"kernel"`` | ``"auto"`` |
``"sharded"`` | ``"sharded2d"``, see ``core.maecho`` — per-leaf
routing is compiled once per model shape into ``core.plan.AggPlan``
and reused across rounds); for the sharded backends pass the mesh
through ``run_multi_round(..., mesh=...)`` (default: a 1-D mesh over
every visible device).  Scan-over-layers models (leaves with leading
stacked-layer axes) ride the same fast paths: pass their per-leaf
axis counts via ``run_multi_round(..., stack_levels=...)`` and the
layer axis folds into the kernel grid instead of forcing the oracle.

Cross-device cohorts: ``MultiRoundConfig.hierarchy_group_size`` > 0
routes the maecho round through the two-tier
:func:`maecho_aggregate_hierarchical` — silo groups aggregate
independently, then the group aggregates aggregate once more — so no
single QP or residual pass ever spans the whole cohort.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np

from repro.core.maecho import (MAEchoConfig, default_projections,
                               maecho_aggregate)
from repro.fl import models as pm
from repro.fl.client import (LocalTrainConfig, compute_projections,
                             evaluate_classifier, train_classifier)
from repro.fl.server import _flatten_convs, _unflatten_convs


@dataclasses.dataclass(frozen=True)
class MultiRoundConfig:
    n_rounds: int = 10
    n_clients: int = 10
    sample_clients: int = 5
    method: str = "fedavg"        # fedavg | fedprox | maecho
    local: LocalTrainConfig = LocalTrainConfig(epochs=10)
    maecho: MAEchoConfig = MAEchoConfig(tau=20, eta=0.5)
    # "auto" promotes big leaves to the fused Pallas pipeline on TPU;
    # "sharded" additionally splits eligible leaves' out-rows over the
    # mesh and "sharded2d" the residual 2-D (out × in) over both mesh
    # axis groups (run_multi_round's ``mesh`` argument).  The default
    # stays "oracle" because interpret-mode kernel execution (this
    # container) is simulation, not a speedup.
    maecho_backend: str = "oracle"  # oracle|kernel|auto|sharded|sharded2d
    proj_alpha: float = 1.0
    seed: int = 0
    # > 0 switches the maecho round to the two-tier hierarchical
    # aggregation (:func:`maecho_aggregate_hierarchical`): the sampled
    # cohort is split into silo groups of this size, each group
    # aggregates independently, and the group outputs are aggregated
    # once more.  0 = flat single-tier (the paper's cross-silo mode).
    hierarchy_group_size: int = 0


def maecho_aggregate_hierarchical(
    client_weights,
    projections=None,
    cfg: MAEchoConfig = MAEchoConfig(),
    *,
    group_size: int,
    convention: str = "oi",
    stack_levels=None,
    backend: str = "oracle",
    mesh=None,
    client_mask=None,
    tier2_cfg: Optional[MAEchoConfig] = None,
):
    """Two-tier MA-Echo for cross-device cohorts: aggregate silo
    groups of ``group_size`` clients independently (tier 1), then
    aggregate the group aggregates (tier 2).

    Peak client residency drops from the whole cohort to
    ``max(group_size, n_groups)`` per aggregation call — composing
    with ``MAEchoConfig.client_chunk``, which bounds the *residual*
    residency inside each call.  ``client_mask`` reuses the flat
    ragged-participation contract per tier: the cohort-wide (N,) mask
    is sliced into each group's submask, groups with zero participants
    are dropped entirely (they contribute no tier-1 aggregate), and
    every surviving group participates fully in tier 2.  With
    ``group_size >= len(client_weights)`` and a single surviving
    group, the flat single-tier result is returned unchanged — exact
    parity with :func:`repro.core.maecho.maecho_aggregate`.

    Tier-2 projections are the per-leaf mean of each group's
    *participating* members' projectors — an approximation (a mean of
    projectors is not itself a projector; factored ``{"U", "s"}``
    leaves average factor-wise), consistent with the group aggregate
    representing its members' shared row space.  ``tier2_cfg``
    optionally overrides the tier-2 solver config (e.g. fewer outer
    iterations over the small n_groups axis)."""
    n = len(client_weights)
    gs = int(group_size)
    if gs <= 0:
        raise ValueError("group_size must be positive")
    if projections is None:
        projections = default_projections(client_weights)
    mask = None
    if client_mask is not None:
        mask = np.asarray(client_mask, bool)
        if mask.shape != (n,):
            raise ValueError(
                f"client_mask must be ({n},) booleans for the "
                f"hierarchical mode, got shape {mask.shape}")
    tier1_w, tier1_p = [], []
    for start in range(0, n, gs):
        members = list(range(start, min(start + gs, n)))
        if mask is None:
            members_in = members
            sub = None
        else:
            members_in = [i for i in members if mask[i]]
            if not members_in:
                continue                  # empty group: no aggregate
            sub = (None if len(members_in) == len(members)
                   else mask[members[0]:members[-1] + 1])
        gw = [client_weights[i] for i in members]
        gp = [projections[i] for i in members]
        tier1_w.append(maecho_aggregate(
            gw, gp, cfg, convention=convention,
            stack_levels=stack_levels, backend=backend, mesh=mesh,
            client_mask=sub))
        tier1_p.append(jax.tree_util.tree_map(
            lambda *xs: sum(xs) / len(xs),
            *[projections[i] for i in members_in]))
    if not tier1_w:
        raise ValueError(
            "client_mask excludes every client — at least one "
            "participant is required")
    if len(tier1_w) == 1:
        return tier1_w[0]
    return maecho_aggregate(
        tier1_w, tier1_p, tier2_cfg if tier2_cfg is not None else cfg,
        convention=convention, stack_levels=stack_levels,
        backend=backend, mesh=mesh)


def run_multi_round(
    spec: pm.PaperModelSpec,
    client_data: list[tuple[np.ndarray, np.ndarray]],
    test_data: tuple[np.ndarray, np.ndarray],
    cfg: MultiRoundConfig,
    global_init=None,
    on_round: Optional[Callable] = None,
    mesh=None,
    stack_levels=None,
) -> tuple[list, float]:
    """Returns (per-round global accuracies, final accuracy).

    ``mesh`` is threaded into the aggregation call for
    ``maecho_backend="sharded"`` (``core.maecho`` builds a default
    1-D all-devices mesh when it is None); ``stack_levels`` is the
    per-leaf stacked-layer-axis count passed straight through to
    ``maecho_aggregate`` for scan-over-layers models (the paper
    MLP/CNN specs are flat — leave it None there)."""
    rng = np.random.RandomState(cfg.seed)
    params = (global_init if global_init is not None
              else pm.init(spec, jax.random.PRNGKey(cfg.seed)))
    history = []
    for rnd in range(cfg.n_rounds):
        picks = rng.choice(cfg.n_clients, size=cfg.sample_clients,
                           replace=False)
        locals_, projs = [], []
        for k in picks:
            x, y = client_data[k]
            lcfg = cfg.local
            if cfg.method == "fedprox":
                lcfg = dataclasses.replace(
                    lcfg, fedprox_mu=lcfg.fedprox_mu or 0.1)
            p, _ = train_classifier(spec, params, x, y, lcfg,
                                    anchor=params)
            locals_.append(p)
            if cfg.method == "maecho":
                projs.append(compute_projections(
                    spec, p, x, alpha=cfg.proj_alpha))

        flat, shapes = zip(*[_flatten_convs(p) for p in locals_])
        flat = list(flat)
        if cfg.method == "maecho":
            fprojs = [_flatten_proj(pr) for pr in projs]
            if cfg.hierarchy_group_size > 0:
                new = maecho_aggregate_hierarchical(
                    flat, fprojs, cfg.maecho,
                    group_size=cfg.hierarchy_group_size,
                    backend=cfg.maecho_backend, mesh=mesh,
                    stack_levels=stack_levels)
            else:
                new = maecho_aggregate(flat, fprojs, cfg.maecho,
                                       backend=cfg.maecho_backend,
                                       mesh=mesh,
                                       stack_levels=stack_levels)
        else:
            from repro.core.aggregators import fedavg
            new = fedavg(flat)
        params = _unflatten_convs(new, shapes[0])

        acc = evaluate_classifier(spec, params, *test_data)
        history.append(acc)
        if on_round:
            on_round(rnd, acc, params)
    return history, history[-1]


def _flatten_proj(projs):
    # projections are already per-layer {"W": P, "b": ()} dicts; conv
    # projectors were computed on im2col features, matching the
    # flattened conv weight — structure already aligned.
    return projs
