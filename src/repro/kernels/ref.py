"""Pure-jnp oracles for every Pallas kernel (allclose targets).

The ``*_any`` MA-Echo oracles accept every projector kind the core
algebra understands — stacked scalars (N,), diagonals (N, in), dense
(N, in, in) and factored ``{"U": (N, in, k), "s": (N, k)}`` — by
routing through ``core.maecho._apply_P`` (imported lazily: ``core``
imports this package for backend dispatch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.projections import block_update as _block_update
from repro.models.layers import chunked_attention as _chunked_attention


def _residuals(W, V, P, convention: str = "oi"):
    """Rᵢ = (W − Vᵢ)Pᵢ for any projector kind (materialized: oracle)."""
    from repro.core.maecho import _apply_P

    return jax.vmap(lambda v, p: _apply_P(W - v, p, convention))(V, P)


def maecho_update_ref(W, V, P, alpha, eta: float = 1.0):
    """W' = W + η·(−Σᵢ 2αᵢ (W − Vᵢ) Pᵢ) — Eq. 7, direct einsum form."""
    R = jnp.einsum("noi,nij->noj", W[None] - V, P)
    D = -2.0 * jnp.einsum("n,noi->oi", alpha, R)
    return W + eta * D


def maecho_update_ref_any(W, V, P, alpha, eta: float = 1.0,
                          convention: str = "oi"):
    """Eq. 7 for any projector kind, fp32 accumulation like the kernel."""
    R = _residuals(W, V, P, convention).astype(jnp.float32)
    D = -2.0 * jnp.einsum("n,n...->...", alpha.astype(jnp.float32), R)
    return (W.astype(jnp.float32) + eta * D).astype(W.dtype)


def maecho_gram_ref(W, V, P, convention: str = "oi"):
    """G[i, j] = ⟨Rᵢ, Rⱼ⟩ with Rᵢ = (W − Vᵢ)Pᵢ — any projector kind."""
    R = _residuals(W, V, P, convention)
    Rf = R.reshape(R.shape[0], -1).astype(jnp.float32)
    return Rf @ Rf.T


def maecho_v_update_ref(W, V, P, frac: float, norm: bool = False,
                        eps: float = 1e-12, convention: str = "oi"):
    """Eq. 11: Vᵢ' = Vᵢ + Norm(Δᵢ − frac·Δᵢ Pᵢ) — any projector kind."""
    from repro.core.maecho import _apply_P

    def one(v, p):
        delta = W - v
        U = delta - frac * _apply_P(delta, p, convention)
        if norm:
            ax = -1 if convention == "oi" else 0
            nrm = jnp.linalg.norm(U.astype(jnp.float32), axis=ax,
                                  keepdims=True)
            U = U / jnp.maximum(nrm, eps).astype(U.dtype)
        return v + U

    return jax.vmap(one)(V, P)


def rank_downdate_ref(Q, U, A):
    return Q - U @ A @ U.T


def block_rls_update_ref(Q, Xb, alpha: float = 1.0):
    return _block_update(Q, Xb, alpha)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    return _chunked_attention(q, k, v, causal=causal,
                              q_chunk=min(128, q.shape[1]),
                              k_chunk=min(128, k.shape[1]))


def decode_attention_ref(q, k_cache, v_cache, valid_mask):
    """Dense full-window decode oracle (the pre-kernel serving path)."""
    from repro.models.layers import decode_attention_oracle

    return decode_attention_oracle(q, k_cache, v_cache, valid_mask)
