"""Pytree utilities shared across the framework.

Parameters everywhere in repro are nested dicts of jnp arrays.  Layer
stacks use a leading ``L`` axis (scan-over-layers layout), produced by
``stack_layers`` / consumed by ``jax.lax.scan``.
"""
from __future__ import annotations

import re
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def tree_map(fn: Callable, *trees: Pytree) -> Pytree:
    return jax.tree_util.tree_map(fn, *trees)


def tree_zeros_like(tree: Pytree, dtype=None) -> Pytree:
    return tree_map(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return tree_map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return tree_map(jnp.subtract, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return tree_map(lambda x: x * s, a)


def tree_axpy(alpha, x: Pytree, y: Pytree) -> Pytree:
    """alpha * x + y."""
    return tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a: Pytree, b: Pytree):
    """Global inner product across all leaves."""
    leaves = tree_map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree_util.tree_reduce(jnp.add, leaves)


def tree_norm(a: Pytree):
    return jnp.sqrt(tree_dot(a, a))


def tree_size(tree: Pytree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Pytree) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_cast(tree: Pytree, dtype) -> Pytree:
    return tree_map(lambda x: x.astype(dtype), tree)


def stack_layers(layers: Iterable[Pytree]) -> Pytree:
    """Stack a list of identical pytrees along a new leading axis."""
    layers = list(layers)
    return tree_map(lambda *xs: jnp.stack(xs, axis=0), *layers)


def unstack_layers(stacked: Pytree, n: int) -> list[Pytree]:
    return [tree_map(lambda x: x[i], stacked) for i in range(n)]


def tree_paths(tree: Pytree) -> list[tuple[str, Any]]:
    """Flatten to (dotted-path, leaf) pairs, dict keys joined by '.'."""
    out: list[tuple[str, Any]] = []

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        out.append((".".join(parts), leaf))
    return out


def tree_from_paths(pairs: list[tuple[str, Any]]) -> Pytree:
    """Inverse of tree_paths for dict-only trees."""
    root: dict = {}
    for path, leaf in pairs:
        parts = path.split(".")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


def map_with_path(fn: Callable[[str, Any], Any], tree: Pytree) -> Pytree:
    """Map fn(path, leaf) -> new leaf over a tree, preserving structure."""

    def _fn(path, leaf):
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return fn(".".join(parts), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)


def first_match(rules: list[tuple[str, Any]], path: str, default=None):
    """Return the value of the first regex rule matching ``path``."""
    for pattern, value in rules:
        if re.search(pattern, path):
            return value
    return default
