"""Batched serving driver: prefill + decode with a request queue.

Two loops share one jitted serve step:

* **fixed batch** (default): prefill all requests at once, decode in
  lockstep — the classic throughput script.

      PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
          --requests 8 --prompt-len 64 --gen 32

* **continuous batching** (``--arrival``): a pool of ``--slots`` decode
  slots; queued prompts are admitted into freed slots *mid-decode*
  (batch-1 prefill inserted into the slot's cache rows), each slot
  tracking its own position / remaining budget / EOS.  One jitted serve
  step runs over the whole slot batch with a vector of per-slot
  positions.

      PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
          --requests 8 --slots 4 --arrival-every 3 --arrival

The serving window rounds up to the kernel block so decode attention
stays on the Pallas fast path, and both loops pass the bucketed
live-window bound (``w_live``) so a mostly-empty ring buffer is cropped
before the kernel — each bucket (powers of two from 2×block) compiles
once.  Row independence of the decode path makes the two loops emit
identical tokens per request for dense/vlm (pinned in
tests/test_serve.py); moe's capacity router couples rows in a batch
(group capacity depends on how many tokens share the group), so its
``--check-parity`` is not bit-exact.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.kernels.ops import DEFAULT_BLOCK
from repro.models.zoo import get_model

# families with a dense-style {"k","v"} ring-buffer cache (leading
# layer axis, batch axis 1) — the ones the slot loop can admit into
SLOT_FAMILIES = ("dense", "vlm", "moe")


def round_window(n: int, mult: int = DEFAULT_BLOCK) -> int:
    """Smallest multiple of ``mult`` ≥ n (the kernel-eligible window)."""
    return max(mult, -(-int(n) // mult) * mult)


def live_bucket(n_live: int, window: int) -> int:
    """Power-of-two bucket (floor 2×block) covering ``n_live`` slots.

    The decode fast path crops the cache read to this bound
    (``layers.decode_attention`` ``w_live``); bucketing bounds
    recompiles to log2(window/2·block) + 1 serve-step variants.
    """
    b = 2 * DEFAULT_BLOCK
    while b < n_live:
        b *= 2
    return min(b, window)


def pad_kv_to_window(cache, window: int, axis: int = 2):
    """Zero-pad the ring-buffer K/V leaves of a prefill cache to the
    serving window.

    Only ``"k"``/``"v"`` leaves pad (encdec's precomputed cross
    ``"xk"``/``"xv"`` and SSM states keep their shapes); nested dicts
    (hybrid's ``{"mamba": …, "attn": …}``) recurse.  Padded slots are
    invalid under the position-derived mask until decode writes them.
    """
    out = {}
    for name, leaf in cache.items():
        if isinstance(leaf, dict):
            out[name] = pad_kv_to_window(leaf, window, axis)
        elif name in ("k", "v") and leaf.shape[axis] < window:
            widths = [(0, 0)] * leaf.ndim
            widths[axis] = (0, window - leaf.shape[axis])
            out[name] = jnp.pad(leaf, widths)
        else:
            out[name] = leaf
    return out


def _prefill_batch(cfg, prompts, gen: int):
    """(batch dict, pos0, window) for one prefill of ``prompts``."""
    B, P = prompts.shape
    if cfg.family == "encdec":
        Pe = min(P, cfg.encdec.dec_seq - gen)
        batch = {"audio_embeds": jnp.zeros((B, cfg.encdec.enc_seq,
                                            cfg.d_model), cfg.cdtype),
                 "tokens": prompts[:, :Pe]}
        pos0 = Pe
    else:
        batch = {"tokens": prompts}
        pos0 = P
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (B, cfg.vlm.n_patches, cfg.vlm.d_vision), cfg.cdtype)
            pos0 = P + cfg.vlm.n_patches
    if cfg.family in ("ssm", "hybrid"):
        window = max(pos0 + gen, 2 * cfg.ssm.d_conv if cfg.ssm else 0)
    else:
        window = round_window(pos0 + gen)
    return batch, pos0, window


def run_fixed(cfg, model, params, prompts, gen: int):
    """Lockstep fixed-batch serving.  Returns (tokens (B, gen), stats)."""
    B = prompts.shape[0]
    batch, pos0, window = _prefill_batch(cfg, prompts, gen)
    ring = cfg.family not in ("ssm", "hybrid")

    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(params, batch)
    if ring:
        cache = pad_kv_to_window(cache, window)
    jax.block_until_ready(cache)
    t_prefill = time.time() - t0

    serve_step = jax.jit(model.make_serve_step(),
                         static_argnames=("w_live",))
    token = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [token]
    t0 = time.time()
    for t in range(gen - 1):
        pos = pos0 + t
        wl = live_bucket(pos + 1, window) if ring else None
        token, cache = serve_step(params, cache, token, jnp.int32(pos),
                                  w_live=wl)
        out_tokens.append(token)
    jax.block_until_ready(token)
    t_decode = time.time() - t0
    stats = {"t_prefill": t_prefill, "t_decode": t_decode,
             "tok_s": B * (gen - 1) / max(t_decode, 1e-9),
             "window": window}
    return jnp.concatenate(out_tokens, axis=1), stats


def run_arrival(cfg, model, params, prompts, gen: int, slots: int,
                arrival_every: int = 1, eos_id: int | None = None):
    """Continuous batching: admit queued prompts into freed slots
    mid-decode.

    Request r arrives at decode step ``r * arrival_every``; a free slot
    prefills it (batch-1, compiled once) and its K/V rows are inserted
    into the slot batch's cache.  Every decode step runs ONE jitted
    serve step over all ``slots`` rows with per-slot positions; slots
    whose request finished (budget spent or EOS) idle harmlessly until
    re-admission overwrites their rows.  Returns
    ``(outputs: list[list[int]] per request, stats)``.
    """
    if cfg.family not in SLOT_FAMILIES:
        raise ValueError(
            f"continuous batching needs a dense-style KV cache; "
            f"family {cfg.family!r} is not in {SLOT_FAMILIES}")
    R, P = prompts.shape
    _, pos0_req, window = _prefill_batch(cfg, prompts[:1], gen)

    prefill1 = jax.jit(model.prefill)
    serve_step = jax.jit(model.make_serve_step(),
                         static_argnames=("w_live",))

    @jax.jit
    def insert(big, small, slot):
        return jax.tree_util.tree_map(
            lambda b, s: jax.lax.dynamic_update_slice_in_dim(
                b, s.astype(b.dtype), slot, axis=1), big, small)

    cache = model.init_cache(slots, window)
    token = jnp.zeros((slots, 1), jnp.int32)
    positions = np.zeros(slots, np.int64)
    rid_of = [-1] * slots
    remaining = [0] * slots
    outputs: list[list[int]] = [[] for _ in range(R)]
    next_req, step, decode_steps = 0, 0, 0

    t0 = time.time()
    while next_req < R or any(remaining):
        for s in range(slots):
            if (remaining[s] == 0 and next_req < R
                    and next_req * arrival_every <= step):
                r, next_req = next_req, next_req + 1
                batch, _, _ = _prefill_batch(cfg, prompts[r:r + 1], gen)
                logits, pc = prefill1(params, batch)
                cache = insert(cache, pad_kv_to_window(pc, window),
                               jnp.int32(s))
                first = int(jnp.argmax(logits[0, -1]))
                outputs[r].append(first)
                token = token.at[s, 0].set(first)
                positions[s] = pos0_req
                rid_of[s], remaining[s] = r, gen - 1
                if eos_id is not None and first == eos_id:
                    remaining[s] = 0
        if not any(remaining):
            step += 1
            continue
        wl = live_bucket(int(positions.max()) + 1, window)
        token, cache = serve_step(
            params, cache, token,
            jnp.asarray(positions, jnp.int32), w_live=wl)
        tok_host = np.asarray(token[:, 0])
        for s in range(slots):
            if remaining[s] > 0:
                outputs[rid_of[s]].append(int(tok_host[s]))
                positions[s] += 1
                remaining[s] -= 1
                if eos_id is not None and tok_host[s] == eos_id:
                    remaining[s] = 0
        step += 1
        decode_steps += 1
    t_total = time.time() - t0
    n_tok = sum(len(o) for o in outputs)
    stats = {"t_total": t_total, "decode_steps": decode_steps,
             "tok_s": n_tok / max(t_total, 1e-9), "window": window}
    return outputs, stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--attn-backend", default=None,
                    choices=("auto", "kernel", "oracle"),
                    help="override ModelConfig.attn_backend")
    ap.add_argument("--arrival", action="store_true",
                    help="continuous batching: admit requests mid-decode")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots for --arrival")
    ap.add_argument("--arrival-every", type=int, default=1,
                    help="request r arrives at decode step r*this")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--check-parity", action="store_true",
                    help="with --arrival: assert per-request tokens "
                         "match the fixed-batch run (exact for "
                         "dense/vlm; moe routing is batch-coupled)")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if args.attn_backend is not None:
        cfg = cfg.replace(attn_backend=args.attn_backend)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    R, P = args.requests, args.prompt_len
    rng = np.random.RandomState(args.seed)
    prompts = jnp.asarray(rng.randint(0, cfg.vocab, size=(R, P)),
                          jnp.int32)

    if args.arrival:
        outs, stats = run_arrival(cfg, model, params, prompts, args.gen,
                                  slots=min(args.slots, R),
                                  arrival_every=args.arrival_every,
                                  eos_id=args.eos_id)
        print(f"arch={cfg.name} requests={R} prompt={P} gen={args.gen} "
              f"slots={min(args.slots, R)} window={stats['window']} "
              f"arrival_every={args.arrival_every}")
        print(f"continuous batching: {stats['decode_steps']} decode "
              f"steps, {stats['t_total']:.2f}s "
              f"({stats['tok_s']:.1f} tok/s aggregate)")
        print("sample:", outs[0][:16])
        if args.check_parity:
            fixed, _ = run_fixed(cfg, model, params, prompts, args.gen)
            ok = all(np.array_equal(np.asarray(fixed[r]),
                                    np.asarray(outs[r], np.int32))
                     for r in range(R))
            print(f"parity vs fixed batch: {'OK' if ok else 'MISMATCH'}")
            if not ok:
                raise SystemExit(1)
    else:
        gen, stats = run_fixed(cfg, model, params, prompts, args.gen)
        print(f"arch={cfg.name} requests={R} prompt={P} gen={args.gen} "
              f"window={stats['window']}")
        print(f"prefill {stats['t_prefill']:.2f}s; decode "
              f"{stats['t_decode']:.2f}s "
              f"({stats['tok_s']:.1f} tok/s aggregate)")
        print("sample:", np.asarray(gen[0])[:16].tolist())


if __name__ == "__main__":
    main()
