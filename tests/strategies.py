"""Strategies + builders for property-based MA-Echo parity tests.

The strategy layer draws only from the primitives the deterministic
stub in ``_hypothesis_stub.py`` implements (``integers``, ``floats``,
``sampled_from``, ``booleans``, ``lists``) — under the stub each
``@given`` test runs a fixed seeded sample of the same ranges, and
``pip install hypothesis`` upgrades the identical tests to adaptive
search with shrinking.  Strategies therefore draw compact *case
descriptors* (seeds, kind names, shape tuples); the ``build_*``
functions below materialize them into concrete client pytrees with
jax PRNG — mixed leaf shapes (tile-aligned, odd-padding and sub-tile),
both weight conventions, all four projector kinds, stacked-layer
leading axes for stack_levels 0–3, and ragged client masks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from hypothesis import strategies as st

KINDS = ("scalar", "diag", "full", "factored")
CONVENTIONS = ("oi", "io")
# (out_d, in_d) in "oi" terms: one direct-tiling shape, two padding
# shapes, one below a 128-tile (the jnp-oracle ref fallback)
SHAPES = ((128, 128), (256, 140), (200, 256), (48, 64))
# leading stacked-layer axes: stack_levels 0 through 3
LEADS = ((), (2,), (3,), (2, 2), (2, 1, 2))
RANK = 16


def seeds():
    return st.integers(0, 2 ** 20)


def n_clients():
    return st.integers(2, 4)


def kinds():
    return st.sampled_from(KINDS)


def conventions():
    return st.sampled_from(CONVENTIONS)


def shapes():
    return st.sampled_from(SHAPES)


def leads():
    return st.sampled_from(LEADS)


def masked():
    return st.booleans()


def bools():
    return st.booleans()


# --------------------------------------------------------------------------
# builders: descriptor -> concrete pytrees
# --------------------------------------------------------------------------
def make_projector(key, kind: str, lead: tuple, in_d: int,
                   rank: int = RANK):
    """One client's projector leaf of ``kind`` with leading stacked
    axes ``lead`` acting on an ``in_d``-dim input space."""
    if kind == "scalar":
        return (jnp.ones(lead) if lead
                else jnp.ones((), jnp.float32))
    if kind == "diag":
        return jax.random.uniform(key, lead + (in_d,),
                                  minval=0.1, maxval=1.0)
    r = min(rank, in_d)
    U = jnp.linalg.qr(jax.random.normal(key, lead + (in_d, r)))[0]
    s = jax.random.uniform(jax.random.fold_in(key, 1), lead + (r,),
                           minval=0.1, maxval=1.0)
    if kind == "factored":
        return {"U": U, "s": s}
    return jnp.einsum("...ik,...k,...jk->...ij", U, s, U)


def build_case(seed: int, n: int, kind: str, convention: str,
               lead: tuple, shape: tuple, use_mask: bool):
    """Materialize one aggregation case.

    Returns ``(clients, projs, stack_levels, client_mask)``: ``n``
    clients of a two-leaf pytree — the (possibly stacked) matmul leaf
    "W" plus a 1-D bias "b" on the scalar rule, so every case mixes an
    eligible and an always-oracle leaf — with per-leaf stack_levels
    and an optional ragged participation mask (≥1 client kept).
    """
    out_d, in_d = shape
    wshape = lead + ((out_d, in_d) if convention == "oi"
                     else (in_d, out_d))
    clients, projs = [], []
    for i in range(n):
        k = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        clients.append({
            "W": jax.random.normal(k, wshape) * 0.3,
            "b": jax.random.normal(jax.random.fold_in(k, 1),
                                   (out_d,)) * 0.1,
        })
        projs.append({
            "W": make_projector(jax.random.fold_in(k, 2), kind, lead,
                                in_d),
            "b": jnp.ones(()),
        })
    levels = {"W": len(lead), "b": 0}
    mask = None
    if use_mask:
        bits = jax.random.bernoulli(
            jax.random.PRNGKey(seed ^ 0x5EED), 0.6, (n,))
        mask = bits.at[seed % n].set(True)   # ≥1 participant
    return clients, projs, levels, mask


# --------------------------------------------------------------------------
# decode-attention case space (serving fast path vs dense oracle)
# --------------------------------------------------------------------------
# (B, W, Hq, Hkv, D): MHA, GQA 4:1, GQA 8:2, MQA with sub-128 head_dim
DECODE_SHAPES = ((1, 128, 4, 4, 64), (2, 256, 8, 2, 64),
                 (2, 256, 16, 4, 64), (2, 128, 4, 1, 32))


def decode_shapes():
    return st.sampled_from(DECODE_SHAPES)


def fills():
    """Tokens written into the ring buffer so far; the builder lets
    this exceed W to exercise wraparound (position = fill - 1 > W)."""
    return st.integers(1, 640)


def build_decode_case(seed: int, shape: tuple, fill: int):
    """(q, k_cache, v_cache, valid_mask, position) for one decode step.

    ``fill`` tokens have been written into the W-slot ring buffer;
    ``position = fill - 1`` is the slot of the newest token.  When
    ``fill > W`` the buffer has wrapped and every slot is valid —
    the mask uses the ring-distance formula the model layer derives
    from the scalar position.
    """
    B, W, Hq, Hkv, D = shape
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (B, 1, Hq, D))
    kc = jax.random.normal(jax.random.fold_in(k, 1), (B, W, Hkv, D))
    vc = jax.random.normal(jax.random.fold_in(k, 2), (B, W, Hkv, D))
    pos = fill - 1
    idx = jnp.arange(W)
    last_abs = pos - jnp.mod(pos - idx, W)
    valid = jnp.broadcast_to((last_abs >= 0) & (last_abs > pos - W),
                             (B, W))
    return q, kc, vc, valid, pos


def build_layer(seed: int, n: int, kind: str, shape: tuple,
                lead: tuple = ()):
    """Materialize one bare (W, V, P) layer in "oi" kernel layout for
    kernel-level parity tests: W (lead..., out, in), V with the client
    axis in front, P stacked per kind."""
    out_d, in_d = shape
    k = jax.random.PRNGKey(seed)
    W = jax.random.normal(k, lead + (out_d, in_d)) * 0.5
    V = jax.random.normal(jax.random.fold_in(k, 1),
                          (n,) + lead + (out_d, in_d)) * 0.5
    Ps = [make_projector(jax.random.fold_in(k, 10 + i), kind, lead,
                         in_d) for i in range(n)]
    P = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *Ps)
    return W, V, P
