"""Deterministic stand-in for the optional `hypothesis` dev dependency.

`hypothesis` is not baked into the runtime container.  Rather than
skipping the five property-test modules wholesale (they carry most of
the core-algorithm coverage), ``conftest.py`` installs this stub into
``sys.modules`` when the real library is missing: each ``@given`` test
then runs a small fixed number of seeded examples drawn from the same
strategy ranges.  ``pip install hypothesis`` upgrades the suite back to
real adaptive property search with shrinking — nothing else changes.

Only the API surface this repo's tests use is provided: ``given``,
``settings`` and the ``strategies`` constructors ``integers``,
``floats``, ``sampled_from``, ``booleans`` and ``lists``.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import types
import zlib

# cap per-test examples so the stubbed suite stays fast; the real
# library honours the full max_examples the tests request.  The CI
# property lane raises the cap via REPRO_STUB_MAX_EXAMPLES for a
# deeper deterministic sweep of the same strategies.
_DEFAULT_EXAMPLES = 5
_MAX_EXAMPLES_CAP = int(os.environ.get("REPRO_STUB_MAX_EXAMPLES", "8"))


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)


def _integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def _floats(min_value, max_value, **_kw):
    lo, hi = float(min_value), float(max_value)
    return _Strategy(lambda r: lo + (hi - lo) * r.random())


def _sampled_from(elements):
    elems = list(elements)
    return _Strategy(lambda r: elems[r.randrange(len(elems))])


def _booleans():
    return _Strategy(lambda r: bool(r.getrandbits(1)))


def _lists(elem, min_size=0, max_size=10, **_kw):
    def draw(r):
        return [elem.draw(r) for _ in range(r.randint(min_size, max_size))]
    return _Strategy(draw)


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.sampled_from = _sampled_from
strategies.booleans = _booleans
strategies.lists = _lists


def settings(max_examples=None, deadline=None, **_kw):
    def deco(fn):
        if max_examples is not None:
            fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        # every parameter must be strategy-supplied: the stub erases
        # the signature, so a fixture/parametrize arg the real library
        # would resolve would here silently receive a strategy value
        n_params = len(inspect.signature(fn).parameters)
        n_supplied = len(arg_strategies) + len(kw_strategies)
        if n_params != n_supplied:
            raise TypeError(
                f"hypothesis stub: {fn.__name__} has {n_params} "
                f"parameters but @given supplies {n_supplied} "
                "strategies; mixing fixtures with @given needs the "
                "real hypothesis (pip install hypothesis)")

        # test identity folded into the seed (crc32: deterministic
        # across processes, unlike hash()): otherwise every test draws
        # the IDENTICAL value sequence from shared strategies and a
        # sampled_from category can be globally unreachable
        fn_salt = zlib.crc32(fn.__qualname__.encode())

        @functools.wraps(fn)
        def wrapper(*args, **kw):
            n = getattr(wrapper, "_stub_max_examples", None)
            if n is None:
                n = getattr(fn, "_stub_max_examples", _DEFAULT_EXAMPLES)
            for ex in range(min(n, _MAX_EXAMPLES_CAP)):
                # fresh seeded stream per example: deterministic across
                # runs, varied across examples and across tests
                r = random.Random((0xA11CE ^ fn_salt) + 7919 * ex)
                vals = [s.draw(r) for s in arg_strategies]
                kwvals = {k: s.draw(r) for k, s in kw_strategies.items()}
                fn(*args, *vals, **kw, **kwvals)
        wrapper.is_hypothesis_stub = True
        # strategy-provided params are not pytest fixtures: hide the
        # wrapped signature from collection (as real hypothesis does)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
