"""qwen2-moe-a2.7b — 4 shared + 60 routed experts, top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B]. 24L d_model=2048 16H MHA d_ff=1408/expert."""
from repro.configs.common import smoke_reduce
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=151936, head_dim=128, qkv_bias=True,
        moe=MoEConfig(n_experts=60, top_k=4, n_shared_experts=4,
                      capacity_factor=1.25),
        microbatches=4,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config(), n_heads=4, n_kv_heads=4)
