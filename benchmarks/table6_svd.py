"""Paper Table 6: SVD compression of the projection matrices —
communication size vs aggregation accuracy."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_DATA, MLP, row, timed, train_locals
from repro.core.maecho import MAEchoConfig
from repro.core.projections import svd_compress, svd_restore
from repro.data.synthetic import generate
from repro.fl.client import evaluate_classifier
from repro.fl.server import one_shot_aggregate


def _compress(projs, k_fracs):
    """Keep k = frac·d principal components per layer."""
    out = []
    nbytes = 0
    for p in projs:
        comp = []
        for lay in p:
            P = lay["W"]
            d = P.shape[0]
            k = max(1, int(k_fracs * d))
            U, s = svd_compress(P, k)
            nbytes += U.size * 4 + s.size * 4
            comp.append({**lay, "W": svd_restore(U, s)})
        out.append(comp)
    return out, nbytes


def run(quick: bool = False):
    data = generate(BENCH_DATA)
    n = 5 if quick else 10
    parts, clients, projs, local = train_locals(
        MLP, data, n, 0.5, epochs=4 if quick else 6)
    full_bytes = sum(lay["W"].size * 4 for p in projs for lay in p)

    fracs = [1.0, 0.1] if quick else [1.0, 0.25, 0.1, 0.03, 0.01]
    for frac in fracs:
        if frac == 1.0:
            cp, nbytes = projs, full_bytes
        else:
            cp, nbytes = _compress(projs, frac)
        g, us = timed(one_shot_aggregate, MLP, clients, cp, "maecho",
                      cfg=MAEchoConfig(tau=30, eta=0.5, mu=20.0))
        acc = evaluate_classifier(MLP, g, data["test_x"],
                                  data["test_y"])
        row(f"table6/keep{frac}", us,
            f"acc={acc:.4f};params_MB={nbytes/1e6:.3f};"
            f"ratio={nbytes/full_bytes:.3f}")

    # beyond-paper: P kept FACTORED through the compute (§Perf H3) —
    # same accuracy as restore, lower aggregation time and memory
    from repro.core.projections import factor_projection_tree
    for frac in ([0.1] if quick else [0.25, 0.1]):
        k = {p[0]["W"].shape[0]: 0 for p in projs}  # per-layer d
        cp = [factor_projection_tree(
            p, max(1, int(frac * max(lay["W"].shape[0]
                                     for lay in p)))) for p in projs]
        g, us = timed(one_shot_aggregate, MLP, clients, cp, "maecho",
                      cfg=MAEchoConfig(tau=30, eta=0.5, mu=20.0))
        acc = evaluate_classifier(MLP, g, data["test_x"],
                                  data["test_y"])
        row(f"table6/factored{frac}", us, f"acc={acc:.4f}")


if __name__ == "__main__":
    run()
