"""Client-axis scaling of MA-Echo aggregation (ISSUE 10 tentpole).

Sweeps the client count N ∈ {8, 64, 512, 4096} over one factored-
projector leaf and measures, for the unchunked jnp path (full
(N, out, in) residual resident) vs the client-chunked sweep
(``ops.maecho_streaming_gram_chunked`` + apply, chunk clients
resident), BOTH wall-clock and the compiled program's peak temp-
buffer footprint (``compiled.memory_analysis().temp_size_in_bytes``)
— the rows carry ``peak_bytes`` and the regression gate checks the
two metrics independently.

The timed/measured unit is one leaf-level gram + apply with a FIXED
uniform α (no QP inside the jit), so the memory analysis isolates
exactly the residual-liveness difference the chunking targets.  The
QP scaling rows time ``qp.solve_qp`` vs ``qp.solve_qp_blocked`` on
the (N, N) Gram separately.

Acceptance rows (asserted here, so a regression fails the suite):
at N=512 / chunk=64 the chunked path's peak temp bytes must be ≥4×
lower than the unchunked path's at ≤1.3× its wall-clock; the N=4096
row (chunked only — the unchunked residual would be 4096× the leaf)
runs at quick-scale dims in every mode and must simply complete.

Rows land in ``BENCH_largeN_agg.json`` via ``benchmarks.run``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row

CHUNK = 64


def _case(N: int, out_d: int, in_d: int, rank: int):
    k = jax.random.PRNGKey(N)
    W = jax.random.normal(k, (out_d, in_d)) * 0.3
    V = jax.random.normal(jax.random.fold_in(k, 1),
                          (N, out_d, in_d)) * 0.3
    U = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(k, 2),
                                        (N, in_d, rank)))[0]
    s = jax.random.uniform(jax.random.fold_in(k, 3), (N, rank),
                           minval=0.1, maxval=1.0)
    return W, V, {"U": U, "s": s}


def _unchunked_step(W, V, P):
    """The oracle-shaped baseline: full (N, out, in) fp32 residual
    materialized for the Gram, again for Eq. 7/11 — the O(N) peak the
    chunked sweep removes."""
    from repro.kernels import ref

    N = V.shape[0]
    alpha = jnp.full((N,), 1.0 / N, jnp.float32)
    G = ref.maecho_gram_ref(W, V, P)
    Wn = ref.maecho_update_ref_any(W, V, P, alpha, eta=0.5)
    Vn = ref.maecho_v_update_ref(Wn, V, P, 0.5, norm=True)
    return G, Wn, Vn


def _chunked_step(W, V, P, chunk: int):
    from repro.kernels import ops

    N = V.shape[0]
    alpha = jnp.full((N,), 1.0 / N, jnp.float32)
    G, ctx = ops.maecho_streaming_gram_chunked(W, V, P, chunk=chunk)
    Wn, Vn = ops.maecho_streaming_apply_chunked(alpha, ctx, eta=0.5,
                                                frac=0.5, norm=True)
    return G, Wn, Vn


def _measure(fn, args, reps: int = 3):
    """(best-of wall-clock µs, peak temp bytes) of one jitted call."""
    jitted = jax.jit(fn)
    mem = jitted.lower(*args).compile().memory_analysis()
    peak = int(getattr(mem, "temp_size_in_bytes", 0) or 0)
    out = jitted(*args)
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
    best = 1e30
    for _ in range(reps):
        t0 = time.perf_counter()
        out = jitted(*args)
        jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, peak


def _qp_rows(N: int, tag: str, iters: int):
    from repro.core import qp

    k = jax.random.PRNGKey(N + 1)
    X = jax.random.normal(k, (N, min(N, 256))) * 0.5
    G = X @ X.T + 0.1 * jnp.eye(N)
    flat_us, _ = _measure(
        lambda g: qp.solve_qp(g, 0.6, iters=iters), (G,))
    blk_us, _ = _measure(
        lambda g: qp.solve_qp_blocked(g, 0.6, iters=iters,
                                      row_block=CHUNK), (G,))
    row(f"largeN_agg/qp_flat_{tag}", flat_us, f"iters={iters}")
    row(f"largeN_agg/qp_blocked_{tag}", blk_us,
        f"iters={iters};rb={CHUNK}")


def run(quick: bool = False):
    out_d, in_d, rank = (128, 128, 8) if quick else (256, 256, 16)
    sweep = [8, 64] if quick else [8, 64, 512]

    ratio = {}
    for N in sweep:
        W, V, P = _case(N, out_d, in_d, rank)
        tag = f"{out_d}x{in_d}_N{N}"
        un_us, un_peak = _measure(_unchunked_step, (W, V, P))
        ch_us, ch_peak = _measure(
            lambda W, V, P: _chunked_step(W, V, P, CHUNK), (W, V, P))
        row(f"largeN_agg/unchunked_{tag}", un_us, "path=oracle",
            peak_bytes=un_peak)
        row(f"largeN_agg/chunked{CHUNK}_{tag}", ch_us, "path=chunked",
            peak_bytes=ch_peak)
        ratio[N] = (un_peak / max(ch_peak, 1), ch_us / max(un_us, 1))

    if not quick:
        # the tentpole acceptance: chunking at N=512 must actually buy
        # the memory (≥4×) without giving the time back (≤1.3×)
        mem_x, time_x = ratio[512]
        row("largeN_agg/ratio_512_c64", 0,
            f"mem_x={mem_x:.2f};time_x={time_x:.2f}")
        assert mem_x >= 4.0, (
            f"chunked peak memory only {mem_x:.2f}x below unchunked "
            f"at N=512/chunk={CHUNK} (need >=4x)")
        assert time_x <= 1.3, (
            f"chunked wall-clock {time_x:.2f}x the unchunked path at "
            f"N=512/chunk={CHUNK} (need <=1.3x)")

    _qp_rows(64 if quick else 512, "N64" if quick else "N512",
             iters=60 if quick else 200)

    # the cross-device headline: N=4096 completes, chunked only, at
    # quick-scale dims in EVERY mode — the unchunked residual
    # (4096·out·in fp32) is the thing this bench exists to delete
    N = 4096
    W, V, P = _case(N, 32, 32, 8)
    ch_us, ch_peak = _measure(
        lambda W, V, P: _chunked_step(W, V, P, CHUNK), (W, V, P),
        reps=1)
    row(f"largeN_agg/chunked{CHUNK}_32x32_N{N}", ch_us,
        "path=chunked;quick_scale", peak_bytes=ch_peak)
    _qp_rows(N, f"N{N}", iters=30)


if __name__ == "__main__":
    run()
