"""Multi-round FL with MA-Echo replacing the averaging operator
(paper §7.4 / Figure 9): faster convergence than FedAvg/FedProx at
strong label skew.

  PYTHONPATH=src python examples/multiround_fl.py
"""
import dataclasses

from repro.core.maecho import MAEchoConfig
from repro.data.partition import label_shard_partition
from repro.data.synthetic import MNIST_LIKE, generate
from repro.fl import models as pm
from repro.fl.client import LocalTrainConfig
from repro.fl.rounds import MultiRoundConfig, run_multi_round


def main():
    data = generate(MNIST_LIKE)
    spec = dataclasses.replace(pm.MLP_SPEC, hidden=(200, 100, 50))
    n_clients, n_labels = 10, 2
    parts = label_shard_partition(data["train_y"], n_clients, n_labels,
                                  seed=0)
    client_data = [(data["train_x"][ix], data["train_y"][ix])
                   for ix in parts]

    for method in ("fedavg", "fedprox", "maecho"):
        cfg = MultiRoundConfig(
            n_rounds=5, n_clients=n_clients, sample_clients=5,
            method=method,
            local=LocalTrainConfig(
                epochs=3, max_steps=80,
                fedprox_mu=0.1 if method == "fedprox" else 0.0),
            maecho=MAEchoConfig(tau=20, eta=0.5, mu=20.0))
        hist, final = run_multi_round(
            spec, client_data, (data["test_x"], data["test_y"]), cfg)
        print(f"{method:8s} " +
              " ".join(f"{a:.3f}" for a in hist))


if __name__ == "__main__":
    main()
