"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The mel-spectrogram + conv feature extractor is the allowed STUB:
``input_specs`` supplies precomputed frame embeddings (B, S_enc, d).
Everything downstream — bidirectional encoder, causal decoder with
cross attention, learned positional embeddings, GELU MLPs, pre-LN with
biases (whisper uses LayerNorm, not RMSNorm) — is implemented.

Decode: self-attention ring-buffer cache of ``seq_len`` (mechanical per
the assigned decode shapes) + precomputed cross K/V from the encoder.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig


def _mha_init(rng, cfg: ModelConfig, n_layers: int, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd()
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 4)

    def stk(k, a, b):
        kk = jax.random.split(k, n_layers)
        return jnp.stack([L.dense_init(q, a, b, cfg.pdtype) for q in kk])

    pre = "x" if cross else ""
    return {
        f"w{pre}q": stk(ks[0], d, Hq * hd),
        f"w{pre}k": stk(ks[1], d, Hkv * hd),
        f"w{pre}v": stk(ks[2], d, Hkv * hd),
        f"w{pre}o": stk(ks[3], Hq * hd, d),
        f"b{pre}q": jnp.zeros((n_layers, Hq * hd), cfg.pdtype),
        f"b{pre}v": jnp.zeros((n_layers, Hkv * hd), cfg.pdtype),
        f"b{pre}o": jnp.zeros((n_layers, d), cfg.pdtype),
    }


def _mlp_init(rng, cfg: ModelConfig, n_layers: int):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 2)

    def stk(k, a, b):
        kk = jax.random.split(k, n_layers)
        return jnp.stack([L.dense_init(q, a, b, cfg.pdtype) for q in kk])

    return {
        "w_in": stk(ks[0], d, f), "b_in": jnp.zeros((n_layers, f), cfg.pdtype),
        "w_out": stk(ks[1], f, d), "b_out": jnp.zeros((n_layers, d), cfg.pdtype),
    }


def _ln_init(n_layers: int, d: int, dtype, name: str):
    return {f"{name}_g": jnp.ones((n_layers, d), dtype),
            f"{name}_b": jnp.zeros((n_layers, d), dtype)}


def init_params(cfg: ModelConfig, rng):
    e = cfg.encdec
    d = cfg.d_model
    ks = jax.random.split(rng, 8)
    enc_layers = {
        **_ln_init(e.n_enc_layers, d, cfg.pdtype, "ln1"),
        **_ln_init(e.n_enc_layers, d, cfg.pdtype, "ln2"),
        **_mha_init(ks[0], cfg, e.n_enc_layers),
        **_mlp_init(ks[1], cfg, e.n_enc_layers),
    }
    dec_layers = {
        **_ln_init(cfg.n_layers, d, cfg.pdtype, "ln1"),
        **_ln_init(cfg.n_layers, d, cfg.pdtype, "ln2"),
        **_ln_init(cfg.n_layers, d, cfg.pdtype, "ln3"),
        **_mha_init(ks[2], cfg, cfg.n_layers),
        **_mha_init(ks[3], cfg, cfg.n_layers, cross=True),
        **_mlp_init(ks[4], cfg, cfg.n_layers),
    }
    return {
        "embed": L.embed_init(ks[5], cfg.vocab, d, cfg.pdtype),
        "dec_pos": (jax.random.normal(ks[6], (e.dec_seq, d)) * 0.01
                    ).astype(cfg.pdtype),
        "enc_layers": enc_layers,
        "dec_layers": dec_layers,
        "ln_enc_g": jnp.ones((d,), cfg.pdtype),
        "ln_enc_b": jnp.zeros((d,), cfg.pdtype),
        "ln_f_g": jnp.ones((d,), cfg.pdtype),
        "ln_f_b": jnp.zeros((d,), cfg.pdtype),
    }


def _mha(lp, xq, xkv, cfg: ModelConfig, *, causal, pre="",
         kv_override=None):
    B, Sq, _ = xq.shape
    hd = cfg.hd()
    q = (xq @ lp[f"w{pre}q"].astype(cfg.cdtype)
         + lp[f"b{pre}q"].astype(cfg.cdtype))
    q = q.reshape(B, Sq, cfg.n_heads, hd)
    if kv_override is not None:
        k, v = kv_override
    else:
        k = xkv @ lp[f"w{pre}k"].astype(cfg.cdtype)
        v = (xkv @ lp[f"w{pre}v"].astype(cfg.cdtype)
             + lp[f"b{pre}v"].astype(cfg.cdtype))
        k = k.reshape(B, xkv.shape[1], cfg.n_kv_heads, hd)
        v = v.reshape(B, xkv.shape[1], cfg.n_kv_heads, hd)
    o = L.prefill_attention(q, k, v, causal=causal,
                            q_chunk=cfg.attn_chunk_q,
                            k_chunk=cfg.attn_chunk_k,
                            unroll=cfg.unroll_layers,
                            backend=cfg.attn_backend)
    return (o.reshape(B, Sq, cfg.n_heads * hd) @
            lp[f"w{pre}o"].astype(cfg.cdtype)
            + lp[f"b{pre}o"].astype(cfg.cdtype))


def encode(cfg: ModelConfig, params, audio_embeds):
    """audio_embeds: (B, S_enc, d) from the stub conv frontend."""
    x = audio_embeds.astype(cfg.cdtype)
    S = x.shape[1]
    # sinusoidal positions (whisper encoder)
    d = cfg.d_model
    pos = jnp.arange(S)[:, None]
    idx = jnp.arange(d // 2)[None]
    ang = pos / jnp.power(10000.0, 2 * idx / d)
    x = x + jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                            axis=-1).astype(cfg.cdtype)

    def body(x, lp):
        h = L.layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
        x = x + _mha(lp, h, h, cfg, causal=False)
        h = L.layer_norm(x, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
        x = x + L.gelu_mlp(h, lp["w_in"].astype(cfg.cdtype),
                           lp["b_in"].astype(cfg.cdtype),
                           lp["w_out"].astype(cfg.cdtype),
                           lp["b_out"].astype(cfg.cdtype))
        return x, None

    body_ = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_, x, params["enc_layers"],
                        unroll=cfg.encdec.n_enc_layers
                        if cfg.unroll_layers else 1)
    return L.layer_norm(x, params["ln_enc_g"], params["ln_enc_b"],
                        cfg.norm_eps)


def decode_train(cfg: ModelConfig, params, tokens, enc_out):
    x = params["embed"].astype(cfg.cdtype)[tokens]
    S = tokens.shape[1]
    x = x + params["dec_pos"].astype(cfg.cdtype)[:S]

    def body(x, lp):
        h = L.layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
        x = x + _mha(lp, h, h, cfg, causal=True)
        h = L.layer_norm(x, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
        x = x + _mha(lp, h, enc_out, cfg, causal=False, pre="x")
        h = L.layer_norm(x, lp["ln3_g"], lp["ln3_b"], cfg.norm_eps)
        x = x + L.gelu_mlp(h, lp["w_in"].astype(cfg.cdtype),
                           lp["b_in"].astype(cfg.cdtype),
                           lp["w_out"].astype(cfg.cdtype),
                           lp["b_out"].astype(cfg.cdtype))
        return x, None

    body_ = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_, x, params["dec_layers"], unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = L.layer_norm(x, params["ln_f_g"], params["ln_f_b"], cfg.norm_eps)
    return x @ params["embed"].astype(cfg.cdtype).T


def forward(cfg: ModelConfig, params, batch):
    enc_out = encode(cfg, params, batch["audio_embeds"])
    return decode_train(cfg, params, batch["tokens"], enc_out)


def loss_fn(cfg: ModelConfig, params, batch):
    return L.softmax_xent(forward(cfg, params, batch), batch["labels"],
                          batch.get("loss_mask"))


def prefill(cfg: ModelConfig, params, batch):
    """Encode audio + run the decoder prompt; returns (last_logits,
    cache) with self-attn K/V of the prompt placed in the ring buffer
    and cross K/V precomputed from the encoder output."""
    enc_out = encode(cfg, params, batch["audio_embeds"])
    xk, xv = precompute_cross_cache(cfg, params, enc_out)
    tokens = batch["tokens"]
    B, Sd = tokens.shape
    x = params["embed"].astype(cfg.cdtype)[tokens]
    x = x + params["dec_pos"].astype(cfg.cdtype)[:Sd]
    hd = cfg.hd()

    def body(x, scanned):
        lp, xk_l, xv_l = scanned
        h = L.layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
        q = (h @ lp["wq"].astype(cfg.cdtype) + lp["bq"].astype(cfg.cdtype)
             ).reshape(B, Sd, cfg.n_heads, hd)
        k = (h @ lp["wk"].astype(cfg.cdtype)).reshape(B, Sd,
                                                      cfg.n_kv_heads, hd)
        v = (h @ lp["wv"].astype(cfg.cdtype) + lp["bv"].astype(cfg.cdtype)
             ).reshape(B, Sd, cfg.n_kv_heads, hd)
        o = L.prefill_attention(q, k, v, causal=True,
                                q_chunk=cfg.attn_chunk_q,
                                k_chunk=cfg.attn_chunk_k,
                                unroll=cfg.unroll_layers,
                                backend=cfg.attn_backend)
        x = x + (o.reshape(B, Sd, cfg.n_heads * hd)
                 @ lp["wo"].astype(cfg.cdtype) + lp["bo"].astype(cfg.cdtype))
        h = L.layer_norm(x, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
        x = x + _mha(lp, h, None, cfg, causal=False, pre="x",
                     kv_override=(xk_l, xv_l))
        h = L.layer_norm(x, lp["ln3_g"], lp["ln3_b"], cfg.norm_eps)
        x = x + L.gelu_mlp(h, lp["w_in"].astype(cfg.cdtype),
                           lp["b_in"].astype(cfg.cdtype),
                           lp["w_out"].astype(cfg.cdtype),
                           lp["b_out"].astype(cfg.cdtype))
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, (params["dec_layers"], xk, xv),
                               unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = L.layer_norm(x[:, -1:], params["ln_f_g"], params["ln_f_b"],
                     cfg.norm_eps)
    logits = x @ params["embed"].astype(cfg.cdtype).T
    return logits, {"k": ks, "v": vs, "xk": xk, "xv": xv}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, window: int):
    nL, hd, e = cfg.n_layers, cfg.hd(), cfg.encdec
    kv = lambda s: jnp.zeros(  # noqa: E731
        (nL, batch, s, cfg.n_kv_heads, hd), cfg.cdtype)
    return {"k": kv(window), "v": kv(window),
            "xk": kv(e.enc_seq), "xv": kv(e.enc_seq)}


def precompute_cross_cache(cfg: ModelConfig, params, enc_out):
    """Project encoder output to per-layer cross K/V once."""
    hd = cfg.hd()
    B, S, _ = enc_out.shape

    def per_layer(lp):
        k = (enc_out @ lp["wxk"].astype(cfg.cdtype)
             ).reshape(B, S, cfg.n_kv_heads, hd)
        v = (enc_out @ lp["wxv"].astype(cfg.cdtype)
             + lp["bxv"].astype(cfg.cdtype)
             ).reshape(B, S, cfg.n_kv_heads, hd)
        return k, v

    ks, vs = jax.vmap(per_layer)(
        {k: params["dec_layers"][k] for k in ("wxk", "wxv", "bxv")})
    return ks, vs


def decode_step(cfg: ModelConfig, params, cache, token, position, *,
                w_live: int | None = None):
    x = params["embed"].astype(cfg.cdtype)[token]
    e = cfg.encdec
    pos_clip = jnp.minimum(position, e.dec_seq - 1)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"].astype(cfg.cdtype), pos_clip, 1, axis=0)
    hd = cfg.hd()

    def body(x, scanned):
        lp, kc, vc, xk, xv = scanned
        B = x.shape[0]
        # self attention against ring buffer
        h = L.layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
        q = (h @ lp["wq"].astype(cfg.cdtype) + lp["bq"].astype(cfg.cdtype)
             ).reshape(B, 1, cfg.n_heads, hd)
        k = (h @ lp["wk"].astype(cfg.cdtype)).reshape(B, 1, cfg.n_kv_heads, hd)
        v = (h @ lp["wv"].astype(cfg.cdtype) + lp["bv"].astype(cfg.cdtype)
             ).reshape(B, 1, cfg.n_kv_heads, hd)
        newc, valid = L.update_kv_cache({"k": kc, "v": vc}, k, v, position)
        o = L.decode_attention(q, newc["k"], newc["v"], valid,
                               backend=cfg.attn_backend, w_live=w_live)
        x = x + (o.reshape(B, 1, cfg.n_heads * hd)
                 @ lp["wo"].astype(cfg.cdtype) + lp["bo"].astype(cfg.cdtype))
        # cross attention against precomputed encoder K/V
        h = L.layer_norm(x, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
        q = (h @ lp["wxq"].astype(cfg.cdtype) + lp["bxq"].astype(cfg.cdtype)
             ).reshape(B, 1, cfg.n_heads, hd)
        valid_x = jnp.ones((xk.shape[0], xk.shape[1]), bool)
        # enc_seq (1500) is not a block multiple — "auto" keeps the
        # cross attention on the dense oracle without a forced warn
        o = L.decode_attention(
            q, xk, xv, valid_x,
            backend="oracle" if cfg.attn_backend == "kernel"
            else cfg.attn_backend)
        x = x + (o.reshape(B, 1, cfg.n_heads * hd)
                 @ lp["wxo"].astype(cfg.cdtype) + lp["bxo"].astype(cfg.cdtype))
        # mlp
        h = L.layer_norm(x, lp["ln3_g"], lp["ln3_b"], cfg.norm_eps)
        x = x + L.gelu_mlp(h, lp["w_in"].astype(cfg.cdtype),
                           lp["b_in"].astype(cfg.cdtype),
                           lp["w_out"].astype(cfg.cdtype),
                           lp["b_out"].astype(cfg.cdtype))
        return x, (newc["k"], newc["v"])

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]), unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = L.layer_norm(x, params["ln_f_g"], params["ln_f_b"], cfg.norm_eps)
    logits = x @ params["embed"].astype(cfg.cdtype).T
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}
