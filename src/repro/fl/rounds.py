"""Multi-round federated learning (paper §5.3 "Applied to Multi-round
Federated Learning" / §7.4).

Each communication round: sample m of N clients, local-train from the
global model, aggregate.  The aggregation operator is pluggable —
``fedavg``, ``fedprox`` (fedavg + proximal local loss), or ``maecho``
(Algorithm 1 replaces the averaging operation, the paper's claim that
it converges in fewer rounds).

The maecho round hands the sampled clients' *whole leaf batch* to one
aggregation call: with ``MAEchoConfig.qp_batched`` (default) every
outer iteration stacks all layers' Gram matrices and issues a single
vmapped PGD solve instead of one QP per layer — the round loop never
serialises over leaves.  ``MultiRoundConfig.maecho_backend`` selects
the per-leaf compute path (``"oracle"`` | ``"kernel"`` | ``"auto"`` |
``"sharded"`` | ``"sharded2d"``, see ``core.maecho`` — per-leaf
routing is compiled once per model shape into ``core.plan.AggPlan``
and reused across rounds); for the sharded backends pass the mesh
through ``run_multi_round(..., mesh=...)`` (default: a 1-D mesh over
every visible device).  Scan-over-layers models (leaves with leading
stacked-layer axes) ride the same fast paths: pass their per-leaf
axis counts via ``run_multi_round(..., stack_levels=...)`` and the
layer axis folds into the kernel grid instead of forcing the oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np

from repro.core.maecho import MAEchoConfig, maecho_aggregate
from repro.fl import models as pm
from repro.fl.client import (LocalTrainConfig, compute_projections,
                             evaluate_classifier, train_classifier)
from repro.fl.server import _flatten_convs, _unflatten_convs


@dataclasses.dataclass(frozen=True)
class MultiRoundConfig:
    n_rounds: int = 10
    n_clients: int = 10
    sample_clients: int = 5
    method: str = "fedavg"        # fedavg | fedprox | maecho
    local: LocalTrainConfig = LocalTrainConfig(epochs=10)
    maecho: MAEchoConfig = MAEchoConfig(tau=20, eta=0.5)
    # "auto" promotes big leaves to the fused Pallas pipeline on TPU;
    # "sharded" additionally splits eligible leaves' out-rows over the
    # mesh and "sharded2d" the residual 2-D (out × in) over both mesh
    # axis groups (run_multi_round's ``mesh`` argument).  The default
    # stays "oracle" because interpret-mode kernel execution (this
    # container) is simulation, not a speedup.
    maecho_backend: str = "oracle"  # oracle|kernel|auto|sharded|sharded2d
    proj_alpha: float = 1.0
    seed: int = 0


def run_multi_round(
    spec: pm.PaperModelSpec,
    client_data: list[tuple[np.ndarray, np.ndarray]],
    test_data: tuple[np.ndarray, np.ndarray],
    cfg: MultiRoundConfig,
    global_init=None,
    on_round: Optional[Callable] = None,
    mesh=None,
    stack_levels=None,
) -> tuple[list, float]:
    """Returns (per-round global accuracies, final accuracy).

    ``mesh`` is threaded into the aggregation call for
    ``maecho_backend="sharded"`` (``core.maecho`` builds a default
    1-D all-devices mesh when it is None); ``stack_levels`` is the
    per-leaf stacked-layer-axis count passed straight through to
    ``maecho_aggregate`` for scan-over-layers models (the paper
    MLP/CNN specs are flat — leave it None there)."""
    rng = np.random.RandomState(cfg.seed)
    params = (global_init if global_init is not None
              else pm.init(spec, jax.random.PRNGKey(cfg.seed)))
    history = []
    for rnd in range(cfg.n_rounds):
        picks = rng.choice(cfg.n_clients, size=cfg.sample_clients,
                           replace=False)
        locals_, projs = [], []
        for k in picks:
            x, y = client_data[k]
            lcfg = cfg.local
            if cfg.method == "fedprox":
                lcfg = dataclasses.replace(
                    lcfg, fedprox_mu=lcfg.fedprox_mu or 0.1)
            p, _ = train_classifier(spec, params, x, y, lcfg,
                                    anchor=params)
            locals_.append(p)
            if cfg.method == "maecho":
                projs.append(compute_projections(
                    spec, p, x, alpha=cfg.proj_alpha))

        flat, shapes = zip(*[_flatten_convs(p) for p in locals_])
        flat = list(flat)
        if cfg.method == "maecho":
            fprojs = [_flatten_proj(pr) for pr in projs]
            new = maecho_aggregate(flat, fprojs, cfg.maecho,
                                   backend=cfg.maecho_backend,
                                   mesh=mesh, stack_levels=stack_levels)
        else:
            from repro.core.aggregators import fedavg
            new = fedavg(flat)
        params = _unflatten_convs(new, shapes[0])

        acc = evaluate_classifier(spec, params, *test_data)
        history.append(acc)
        if on_round:
            on_round(rnd, acc, params)
    return history, history[-1]


def _flatten_proj(projs):
    # projections are already per-layer {"W": P, "b": ()} dicts; conv
    # projectors were computed on im2col features, matching the
    # flattened conv weight — structure already aligned.
    return projs
