"""Helpers shared by the architecture config modules."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


def smoke_reduce(cfg: ModelConfig, **extra) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests:
    2 layers, d_model <= 512, <= 4 experts, tiny vocab, f32 numerics."""
    d = min(cfg.d_model, 256)
    hd = 32
    n_heads = max(2, min(4, cfg.n_heads))
    n_kv = max(1, min(n_heads, cfg.n_kv_heads if cfg.n_kv_heads else 1))
    if n_heads % n_kv:
        n_kv = 1
    kw = dict(
        n_layers=2, d_model=d, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=hd, d_ff=min(cfg.d_ff, 4 * d) if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 512),
        param_dtype="float32", compute_dtype="float32",
        attn_chunk_q=64, attn_chunk_k=64, window=128,
        fsdp=False, remat=False, microbatches=1, seq_shard=False,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(2, cfg.moe.top_k),
            n_shared_experts=min(1, cfg.moe.n_shared_experts),
            group_size=64)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=min(cfg.ssm.d_state, 16),
            head_dim=min(cfg.ssm.head_dim, 32))
    if cfg.hybrid is not None:
        kw["n_layers"] = 2
        kw["hybrid"] = dataclasses.replace(cfg.hybrid, attn_every=2)
    if cfg.encdec is not None:
        kw["encdec"] = dataclasses.replace(
            cfg.encdec, n_enc_layers=2, enc_seq=64, dec_seq=32)
    if cfg.vlm is not None:
        kw["vlm"] = dataclasses.replace(cfg.vlm, n_patches=8, d_vision=64)
    kw.update(extra)
    return cfg.replace(name=cfg.name + "-smoke", **kw)
