"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; the
TPU launch path is the same call with ``interpret=False``.  Shapes that
don't meet the kernels' block-multiple requirements fall back to the
jnp oracle (recorded in the returned aux when ``debug=True``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.maecho_update import maecho_update
from repro.kernels.rank_update import block_rls_update, rank_downdate

__all__ = [
    "flash_attention", "maecho_update", "rank_downdate",
    "block_rls_update", "maecho_update_auto", "flash_attention_auto",
]


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def maecho_update_auto(W, V, P, alpha, *, eta: float = 1.0,
                       block: int = 128, interpret: bool = True):
    """Kernel when 128-alignable (after padding), oracle otherwise."""
    out_d, in_d = W.shape
    if out_d < block or in_d < block:
        return ref.maecho_update_ref(W, V, P, alpha, eta)
    Wp, po = _pad_to(W, block, 0)
    Wp, pi = _pad_to(Wp, block, 1)
    if po or pi:
        Vp, _ = _pad_to(_pad_to(V, block, 1)[0], block, 2)
        Pp, _ = _pad_to(_pad_to(P, block, 1)[0], block, 2)
    else:
        Vp, Pp = V, P
    out = maecho_update(Wp, Vp, Pp, alpha, eta=eta, bo=block, bi=block,
                        bk=block, interpret=interpret)
    return out[:out_d, :in_d]


def flash_attention_auto(q, k, v, *, causal: bool = True, bq: int = 256,
                         bk: int = 256, interpret: bool = True):
    if q.shape[1] % min(bq, q.shape[1]) or k.shape[1] % min(bk, k.shape[1]):
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return flash_attention(q, k, v, causal=causal,
                           bq=min(bq, q.shape[1]), bk=min(bk, k.shape[1]),
                           interpret=interpret)
