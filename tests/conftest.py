"""Shared fixtures, optional-dependency shims and marker registration.

`hypothesis` is an **optional dev dependency** (it is not in the
runtime container image).  When it is missing, the deterministic stub
in ``tests/_hypothesis_stub.py`` is installed into ``sys.modules``
before collection so the property-test modules still collect and run
on a small fixed sample per strategy.  ``pip install hypothesis``
restores full property search.

Markers:
  slow — heaviest smoke/sweep tests.  ``pytest -m "not slow"`` is the
  fast inner loop; tier-1 (plain ``pytest``) still runs everything.
"""
import pathlib
import sys

import jax
import numpy as np
import pytest

# tests/ on sys.path unconditionally: the shared strategy module
# (tests/strategies.py) is imported by name from the property-test
# modules whether or not the real hypothesis is installed
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy model-zoo smoke / sweep tests; deselect with "
        "-m \"not slow\" for a fast inner loop (tier-1 runs all)")


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables():
    """Drop JAX's jit caches after each test module.

    Every XLA:CPU executable pins a handful of ``mmap`` regions for its
    code pages.  A full-suite run in a single process accumulates tens
    of thousands of mappings and eventually crosses the kernel's
    ``vm.max_map_count`` ceiling (65530 by default) — at which point the
    next compile's ``mmap`` fails and XLA segfaults mid-suite.  Clearing
    at module boundaries bounds the live-map count to the heaviest
    single module; cross-module cache reuse is negligible because each
    module compiles its own shapes.
    """
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
