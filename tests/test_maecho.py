"""Algorithm 1 semantics (paper §5) — unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import projections as proj
from repro.core.maecho import (MAEchoConfig, default_projections,
                               init_global, maecho_aggregate)


def _rand_client(seed, shape=(6, 4)):
    k = jax.random.PRNGKey(seed)
    return {"W": jax.random.normal(k, shape),
            "b": jax.random.normal(jax.random.fold_in(k, 1),
                                   (shape[0],))}


def _proj_for(seed, d=4, n=12, alpha=1e-3):
    X = jax.random.normal(jax.random.PRNGKey(100 + seed), (n, d))
    return {"W": proj.projection_from_features(X, alpha),
            "b": jnp.ones(())}


def test_identical_clients_fixed_point():
    """All clients equal ⇒ W⁽⁰⁾ = Wᵢ is Pareto critical: D = 0."""
    c = _rand_client(0)
    ps = [_proj_for(i) for i in range(3)]
    out = maecho_aggregate([c, c, c], ps, MAEchoConfig(tau=5, eta=1.0))
    np.testing.assert_allclose(np.asarray(out["W"]),
                               np.asarray(c["W"]), atol=1e-5)


def test_objective_decreases():
    """Each sub-objective ‖Pᵢ(W − Vᵢ)‖² decreases vs the average init
    (C=1 case of Prop. 1)."""
    clients = [_rand_client(i) for i in range(3)]
    projs = [_proj_for(i, n=2) for i in range(3)]   # low-rank P
    W0 = init_global(clients, "average")
    out, V = maecho_aggregate(clients, projs,
                              MAEchoConfig(tau=30, eta=0.5),
                              return_anchors=True)

    def obj(W, Vs):
        return sum(float(jnp.sum(jnp.square(
            (W["W"] - Vs["W"][i]) @ projs[i]["W"]))) for i in range(3))

    before = obj(W0, {"W": jnp.stack([c["W"] for c in clients])})
    after = obj(out, V)
    assert after < before * 0.5


def test_null_space_knowledge_preserved():
    """The aggregate's deviation from each local optimum stays (mostly)
    out of that client's feature span — the forgetting-alleviation
    mechanism."""
    d = 8
    clients, projs, Xs = [], [], []
    for i in range(2):
        X = jax.random.normal(jax.random.PRNGKey(i), (3, d))  # rank 3
        Xs.append(X)
        clients.append({"W": jax.random.normal(
            jax.random.PRNGKey(10 + i), (5, d))})
        projs.append({"W": proj.projection_from_features(X, 1e-4)})
    # paper Fig. 8: large μ pins the anchors to their feature span;
    # μ=1 (the default) trades some local fidelity for a wider search
    out_hi, V_hi = maecho_aggregate(clients, projs,
                                    MAEchoConfig(tau=50, eta=0.5,
                                                 mu=200.0),
                                    return_anchors=True)
    out_lo, V_lo = maecho_aggregate(clients, projs,
                                    MAEchoConfig(tau=50, eta=0.5,
                                                 mu=1.0),
                                    return_anchors=True)
    for i in range(2):
        def ratio(V):
            drift = np.asarray(Xs[i] @ (V["W"][i] - clients[i]["W"]).T)
            base = np.asarray(Xs[i] @ clients[i]["W"].T)
            return np.abs(drift).max() / np.abs(base).max()

        # μ=200: the anchor's function on client data is intact
        assert ratio(V_hi) < 0.1
        # μ=1 relaxes — strictly more in-span drift (Fig. 8 ordering)
        assert ratio(V_lo) > ratio(V_hi)


def test_default_projections_consensus():
    """Scalar projectors everywhere ⇒ pure consensus pull; W stays
    finite and between the clients."""
    clients = [_rand_client(i) for i in range(4)]
    out = maecho_aggregate(clients, None, MAEchoConfig(tau=10, eta=0.2))
    lo = np.minimum.reduce([np.asarray(c["W"]) for c in clients]).min()
    hi = np.maximum.reduce([np.asarray(c["W"]) for c in clients]).max()
    W = np.asarray(out["W"])
    assert np.all(np.isfinite(W))
    assert W.min() >= lo - 1.0 and W.max() <= hi + 1.0


@pytest.mark.parametrize("init", ["average", "first", "random"])
def test_init_strategies(init):
    clients = [_rand_client(i) for i in range(3)]
    out = maecho_aggregate(clients, None,
                           MAEchoConfig(tau=5, init=init),
                           rng=jax.random.PRNGKey(7))
    assert np.all(np.isfinite(np.asarray(out["W"])))


def test_stacked_levels_match_unstacked():
    """A stacked (L, out, in) leaf must aggregate exactly like L
    separate leaves (the scan-over-layers LLM layout)."""
    L = 3
    clients_flat, projs_flat = [], []
    for i in range(2):
        ws = [jax.random.normal(jax.random.PRNGKey(10 * i + l), (6, 4))
              for l in range(L)]
        ps = [_proj_for(10 * i + l)["W"] for l in range(L)]
        clients_flat.append((ws, ps))

    # per-layer separate aggregation
    outs = []
    for l in range(L):
        out = maecho_aggregate(
            [{"W": clients_flat[0][0][l]}, {"W": clients_flat[1][0][l]}],
            [{"W": clients_flat[0][1][l]}, {"W": clients_flat[1][1][l]}],
            MAEchoConfig(tau=8, eta=0.5))
        outs.append(out["W"])

    # stacked aggregation
    stacked = maecho_aggregate(
        [{"W": jnp.stack(clients_flat[0][0])},
         {"W": jnp.stack(clients_flat[1][0])}],
        [{"W": jnp.stack(clients_flat[0][1])},
         {"W": jnp.stack(clients_flat[1][1])}],
        MAEchoConfig(tau=8, eta=0.5),
        stack_levels=lambda path: 1)
    np.testing.assert_allclose(np.asarray(stacked["W"]),
                               np.asarray(jnp.stack(outs)), atol=1e-5)


def test_conventions_agree_under_transpose():
    """'oi' on W and 'io' on Wᵀ produce transposed-identical results."""
    clients = [{"W": jax.random.normal(jax.random.PRNGKey(i), (6, 4))}
               for i in range(2)]
    projs = [_proj_for(i) for i in range(2)]
    projs = [{"W": p["W"]} for p in projs]
    a = maecho_aggregate(clients, projs, MAEchoConfig(tau=6, eta=0.5),
                         convention="oi")
    b = maecho_aggregate([{"W": c["W"].T} for c in clients], projs,
                         MAEchoConfig(tau=6, eta=0.5), convention="io")
    np.testing.assert_allclose(np.asarray(a["W"]),
                               np.asarray(b["W"]).T, atol=1e-5)


def test_diag_projector_embedding_rule():
    """Diagonal P (token support): rows outside the client's support
    are free to move; supported rows are anchored."""
    vocab, d = 10, 4
    emb = [jax.random.normal(jax.random.PRNGKey(i), (vocab, d))
           for i in range(2)]
    sup = [jnp.asarray(np.r_[np.ones(5), np.zeros(5)], jnp.float32),
           jnp.asarray(np.r_[np.zeros(5), np.ones(5)], jnp.float32)]
    out, V = maecho_aggregate(
        [{"embed": e} for e in emb],
        [{"embed": s} for s in sup],
        MAEchoConfig(tau=20, eta=0.5, mu=200.0), convention="io",
        return_anchors=True)
    # client 0's supported rows: anchor pinned; unsupported rows free
    d0 = np.abs(np.asarray(V["embed"][0] - emb[0]))
    assert d0[:5].max() < 0.05 * d0[5:].max()


def test_factored_projectors_match_full():
    """P kept factored as U·diag(s)·Uᵀ through the compute (§Perf H3)
    gives identical results at exact rank."""
    clients, projs = [], []
    for i in range(3):
        X = jax.random.normal(jax.random.PRNGKey(i), (5, 8))
        clients.append(_rand_client(10 + i, (6, 8)))
        projs.append({"W": proj.projection_from_features(X, 1e-3),
                      "b": jnp.ones(())})
    full = maecho_aggregate(clients, projs, MAEchoConfig(tau=8, eta=0.5))
    fact = [{"W": proj.factor_projection(p["W"], 8), "b": p["b"]}
            for p in projs]
    out = maecho_aggregate(clients, fact, MAEchoConfig(tau=8, eta=0.5))
    np.testing.assert_allclose(np.asarray(full["W"]),
                               np.asarray(out["W"]), atol=1e-4)
    tree = proj.factor_projection_tree(projs[0], 4)
    assert set(tree["W"]) == {"U", "s"}
    assert tree["W"]["U"].shape == (8, 4)


@pytest.mark.slow
@given(st.integers(2, 5), st.floats(0.1, 1.0), st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_always_finite(n_clients, eta, seed):
    clients = [_rand_client(seed * 10 + i) for i in range(n_clients)]
    projs = [_proj_for(seed * 10 + i) for i in range(n_clients)]
    out = maecho_aggregate(clients, projs,
                           MAEchoConfig(tau=10, eta=eta))
    assert all(np.all(np.isfinite(np.asarray(x)))
               for x in jax.tree_util.tree_leaves(out))
