"""qwen2-0.5b — dense GQA with QKV bias [arXiv:2407.10671]."""
from repro.configs.common import smoke_reduce
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab=151936, head_dim=64, qkv_bias=True,
        rope_theta=1000000.0, tie_embeddings=True,
        microbatches=2,
        source="arXiv:2407.10671",
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config(), n_heads=4, n_kv_heads=2)
