"""whisper-tiny — enc-dec audio backbone, conv frontend stubbed
[arXiv:2212.04356]. 4 encoder + 4 decoder layers, d_model=384, 6 heads
(MHA: kv=6), GELU MLP d_ff=1536, vocab 51865."""
from repro.configs.common import smoke_reduce
from repro.models.config import EncDecConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="encdec",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
        d_ff=1536, vocab=51865, head_dim=64, tie_embeddings=True,
        encdec=EncDecConfig(n_enc_layers=4, enc_seq=1500, dec_seq=448),
        source="arXiv:2212.04356",
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config(), n_heads=4, n_kv_heads=4)
