"""Sharding rules + single-device jit of sharded programs.

The full 16×16 / 2×16×16 lower+compile proof lives in the dry-run
driver (it needs the 512-device XLA flag set before jax init); here we
validate the rules' divisibility logic and that sharded programs lower
on the real (1-device) mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import assigned_archs, get_config, get_smoke_config
from repro.launch.mesh import make_debug_mesh
from repro.models.zoo import get_model
from repro.optim import sgd
from repro.sharding.rules import make_rules
from repro.utils import trees


class FakeMesh:
    """Shape-only mesh stand-in for rule unit tests."""

    def __init__(self, shape: dict):
        self.shape = shape


@pytest.mark.slow
@pytest.mark.parametrize("arch", assigned_archs())
def test_param_specs_divisible(arch):
    """Every sharded dim divides by its mesh axis (the rules' promise)."""
    cfg = get_config(arch)
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = make_rules(mesh, cfg)
    m = get_model(cfg)
    pspecs = m.param_specs()

    def check(path, leaf):
        spec = rules.param_spec(path, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            n = 16 if not isinstance(ax, tuple) else \
                int(np.prod([16 for _ in ax]))
            assert dim % n == 0, (path, leaf.shape, spec)
        return leaf

    trees.map_with_path(check, pspecs)


@pytest.mark.slow
@pytest.mark.parametrize("arch", assigned_archs())
def test_big_tensors_are_sharded(arch):
    """No parameter tensor above 64 MB may be fully replicated."""
    cfg = get_config(arch)
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = make_rules(mesh, cfg)
    m = get_model(cfg)

    def check(path, leaf):
        nbytes = int(np.prod(leaf.shape)) * 2
        spec = rules.param_spec(path, leaf.shape)
        if nbytes > 64 * 2 ** 20:
            assert any(ax is not None for ax in spec), (path, leaf.shape)
        return leaf

    trees.map_with_path(check, m.param_specs())


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "falcon_mamba_7b",
                                  "qwen2_moe_a2_7b", "zamba2_2_7b",
                                  "whisper_tiny"])
@pytest.mark.slow
def test_sharded_train_step_lowers_on_debug_mesh(arch):
    """jit with in_shardings on the real 1-device mesh compiles and
    runs for the reduced configs."""
    cfg = get_smoke_config(arch)
    mesh = make_debug_mesh(1, 1)
    rules = make_rules(mesh, cfg)
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    param_sh = rules.params_shardings(m.param_specs())
    opt = sgd(0.01, momentum=0.5)
    opt_state = opt.init(params)
    from repro.models.config import InputShape
    shape = InputShape("t", 16, 2, "train")
    specs = m.input_specs(shape)
    batch = {k: jnp.zeros(v.shape, v.dtype) if v.dtype != jnp.int32
             else jnp.ones(v.shape, jnp.int32) for k, v in specs.items()}
    input_sh = rules.inputs_shardings(specs)
    with mesh:
        step = jax.jit(m.make_train_step(opt),
                       in_shardings=(param_sh, {"m": param_sh},
                                     input_sh, None))
        p2, s2, loss = step(params, opt_state, batch, jnp.int32(0))
    assert np.isfinite(float(loss))


def test_cache_specs_decode():
    cfg = get_config("llama3_8b")
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = make_rules(mesh, cfg)
    # kv heads 8 not divisible by 16 -> head_dim sharded instead
    spec = rules.cache_spec("cache.k", (32, 128, 32768, 8, 128))
    assert spec[3] is None and spec[4] == "model"
    cfg32 = get_config("phi3_vision_4_2b")     # kv=32 divisible
    spec = make_rules(mesh, cfg32).cache_spec(
        "cache.k", (32, 128, 32768, 32, 96))
    assert spec[3] == "model"


def test_batch_specs():
    cfg = get_config("llama3_8b")
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = make_rules(mesh, cfg)
    spec = rules.batch_spec("tokens", (256, 4096))
    assert spec[0] == ("pod", "data")
    # long_500k batch=1: not divisible -> replicated
    spec = rules.batch_spec("token", (1, 1))
    assert spec[0] is None
