"""Null-space projection properties (paper §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import projections as proj


@pytest.mark.slow
@given(st.integers(4, 48), st.integers(2, 60), st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_block_matches_direct(d, n, seed):
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    Pd = proj.projection_direct(X, 1e-4)
    Pb = proj.projection_from_features(X, 1e-4, block=7)
    np.testing.assert_allclose(np.asarray(Pd), np.asarray(Pb),
                               atol=2e-4)


@given(st.integers(4, 32), st.integers(1, 20), st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_projector_properties(d, n, seed):
    """P ≈ Pᵀ, eigenvalues in [0, 1], and P x ≈ x for x in row space."""
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    P = proj.projection_from_features(X, 1e-5)
    P = np.asarray(P)
    np.testing.assert_allclose(P, P.T, atol=1e-5)
    w = np.linalg.eigvalsh(0.5 * (P + P.T))
    assert w.min() > -1e-4 and w.max() < 1 + 1e-4
    x = np.asarray(X)[0]
    np.testing.assert_allclose(P @ x, x, rtol=0.05, atol=1e-2 *
                               np.linalg.norm(x))


def test_null_space_preserves_mapping():
    """Paper's core mechanism: ΔW in the null space of X leaves X·w
    unchanged."""
    rng = jax.random.PRNGKey(0)
    X = jax.random.normal(rng, (30, 16))
    P = proj.projection_from_features(X, 1e-5)
    I_P = jnp.eye(16) - P
    delta = jax.random.normal(jax.random.PRNGKey(1), (16,))
    delta_null = I_P @ delta
    assert float(jnp.max(jnp.abs(X @ delta_null))) < 1e-2 * \
        float(jnp.max(jnp.abs(X @ delta)))


def test_streaming_continue_matches_oneshot():
    X = jax.random.normal(jax.random.PRNGKey(2), (64, 12))
    Q1 = proj.null_projector_from_features(X, 1e-3, block=16)
    Q2 = proj.null_projector_init(12)
    for s in range(0, 64, 16):
        Q2 = proj.null_projector_from_features_continue(
            Q2, X[s:s + 16], 1e-3, block=16)
    np.testing.assert_allclose(np.asarray(Q1), np.asarray(Q2), atol=1e-5)


@pytest.mark.parametrize("k,min_keep", [(16, 0.95), (8, 0.95), (4, 0.45)])
def test_svd_compression(k, min_keep):
    """Paper Table 6: heavy compression keeps most of the projector
    when its energy is concentrated (the regime real features live in)."""
    X = jax.random.normal(jax.random.PRNGKey(3), (200, 32))
    # concentrate energy in a few directions
    X = X * jnp.concatenate([jnp.ones(8) * 3, jnp.ones(24) * 0.01])
    P = proj.projection_from_features(X, 1.0)
    U, s = proj.svd_compress(P, k)
    P2 = proj.svd_restore(U, s)
    keep = float(jnp.trace(P2)) / float(jnp.trace(P))
    assert keep >= min_keep * 0.9
    assert proj.compression_ratio(32, k) < 1.0


def test_owm_rank1_matches_block():
    X = jax.random.normal(jax.random.PRNGKey(4), (8, 10))
    Q1 = proj.null_projector_init(10)
    for i in range(8):
        Q1 = proj.owm_update(Q1, X[i], 1e-2)
    Q2 = proj.null_projector_init(10)
    Q2 = proj.block_update(Q2, X, 1e-2)
    # rank-1 sequence and block differ only by regularisation ordering
    np.testing.assert_allclose(np.asarray(Q1), np.asarray(Q2), atol=0.05)
