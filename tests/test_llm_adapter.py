"""LLM adapter: stack levels, projector shapes, aggregation effect."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.maecho import MAEchoConfig
from repro.fl.llm_adapter import (aggregate_llm, build_projections,
                                  default_llm_projections,
                                  stack_levels_fn)
from repro.models.config import InputShape
from repro.models.zoo import get_model
from repro.utils import trees

ARCHS = ["llama3_8b", "qwen2_moe_a2_7b", "falcon_mamba_7b",
         "zamba2_2_7b", "whisper_tiny", "phi3_vision_4_2b"]


def _batch(m, cfg, seed=0):
    specs = m.input_specs(InputShape("t", 32, 2, "train"))
    rng = jax.random.PRNGKey(seed)
    return {k: (jax.random.randint(rng, v.shape, 0, cfg.vocab
                                   ).astype(jnp.int32)
                if v.dtype == jnp.int32
                else jax.random.normal(rng, v.shape, v.dtype) * 0.1)
            for k, v in specs.items()}


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_projection_shapes_match_rules(arch):
    cfg = get_smoke_config(arch)
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    projs = build_projections(cfg, params, [_batch(m, cfg)])
    lv = stack_levels_fn(cfg)

    def check(path, leaf):
        P = trees.tree_paths(projs)
        return leaf

    pairs_w = dict(trees.tree_paths(params))
    pairs_p = dict(trees.tree_paths(projs))
    assert set(pairs_w) == set(pairs_p)
    for path, W in pairs_w.items():
        P = pairs_p[path]
        levels = lv(path)
        base = W.shape[levels:]
        if path == "embed":
            assert P.shape == (cfg.vocab,)        # diag token support
        elif P.ndim == levels + 2:                # full projector
            d_in = base[0]
            assert P.shape[-2:] == (d_in, d_in)
            assert P.shape[:levels] == W.shape[:levels]
        else:                                     # scalar rule
            assert P.shape == W.shape[:levels]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3_8b", "qwen2_moe_a2_7b"])
def test_aggregation_preserves_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    m = get_model(cfg)
    clients = [m.init_params(jax.random.PRNGKey(i)) for i in range(2)]
    projs = [build_projections(cfg, p, [_batch(m, cfg, seed=i)])
             for i, p in enumerate(clients)]
    g = aggregate_llm(cfg, clients, projs, MAEchoConfig(tau=3, eta=0.5))
    for (pw, w), (pg, gl) in zip(trees.tree_paths(clients[0]),
                                 trees.tree_paths(g)):
        assert w.shape == gl.shape, pw
        assert np.all(np.isfinite(np.asarray(gl, np.float32))), pw


@pytest.mark.slow
def test_moe_expert_projectors_differ_by_expert():
    """Per-expert P built from routed streams must not be identical
    across experts (disjoint token subsets -> distinct row spaces)."""
    cfg = get_smoke_config("qwen2_moe_a2_7b")
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    projs = build_projections(cfg, params, [_batch(m, cfg)])
    P = dict(trees.tree_paths(projs))["layers.we_gate"]
    assert P.ndim == 4                      # (L, E, d, d)
    diffs = float(jnp.max(jnp.abs(P[0, 0] - P[0, 1])))
    assert diffs > 1e-4


def test_default_projections_token_support():
    cfg = get_smoke_config("llama3_8b")
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    sup = jnp.zeros(cfg.vocab).at[:10].set(1.0)
    projs = default_llm_projections(cfg, params, token_support=sup)
    P = dict(trees.tree_paths(projs))["embed"]
    assert P.shape == (cfg.vocab,)
    assert float(P[:10].sum()) == 10.0 and float(P[10:].sum()) == 0.0
