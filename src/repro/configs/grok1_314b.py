"""grok-1-314b — 8 experts top-2 MoE [hf:xai-org/grok-1].
64L d_model=6144 48H (GQA kv=8) d_ff=32768/expert vocab=131072.
fsdp=True: 314B total params require weight sharding over the data
axis as well (see llama3_405b note)."""
from repro.configs.common import smoke_reduce
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=32768, vocab=131072, head_dim=128,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared_experts=0,
                      capacity_factor=1.25),
        fsdp=True, microbatches=16, source="hf:xai-org/grok-1",
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config(), n_heads=4, n_kv_heads=2)
