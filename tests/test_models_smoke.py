"""Per-architecture smoke tests (deliverable f): reduced same-family
configs, one forward + one train step + decode, shape and finiteness
asserts, prefill↔decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import assigned_archs, get_config, get_smoke_config
from repro.models.config import INPUT_SHAPES, InputShape
from repro.models.zoo import get_model
from repro.optim import sgd

ARCHS = assigned_archs()
SHAPE = InputShape("smoke", 32, 2, "train")


def _batch(m, cfg, seed=0):
    specs = m.input_specs(SHAPE)
    rng = jax.random.PRNGKey(seed)
    out = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            out[k] = jax.random.randint(jax.random.fold_in(rng, hash(k) %
                                                           1000),
                                        v.shape, 0, cfg.vocab
                                        ).astype(jnp.int32)
        else:
            out[k] = jax.random.normal(rng, v.shape, v.dtype) * 0.1
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact published numbers."""
    cfg = get_config(arch)
    expect = {
        "llama3_8b": (32, 4096, 32, 8, 14336, 128256),
        "qwen2_1_5b": (28, 1536, 12, 2, 8960, 151936),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
        "falcon_mamba_7b": (64, 4096, 0, 0, 0, 65024),
        "phi3_vision_4_2b": (32, 3072, 32, 32, 8192, 32064),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "llama3_405b": (126, 16384, 128, 8, 53248, 128256),
        "zamba2_2_7b": (54, 2560, 32, 32, 10240, 32000),
        "qwen2_0_5b": (24, 896, 14, 2, 4864, 151936),
        "grok1_314b": (64, 6144, 48, 8, 32768, 131072),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect
    assert cfg.source


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_reduction_limits(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 or (cfg.hybrid and cfg.n_layers <= 4)
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = _batch(m, cfg)
    loss = m.loss_fn(params, batch)
    assert np.isfinite(float(loss))

    opt = sgd(0.05, momentum=0.5)
    step = jax.jit(m.make_train_step(opt))
    p2, s2, l0 = step(params, opt.init(params), batch, jnp.int32(0))
    _, _, l1 = step(p2, s2, batch, jnp.int32(1))
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0)          # one step on same batch helps


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_decode_steps_finite(arch):
    cfg = get_smoke_config(arch)
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    cache = m.init_cache(2, 16)
    tok = jnp.ones((2, 1), jnp.int32)
    step = jax.jit(m.decode_step)
    for pos in range(4):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["llama3_8b", "qwen2_0_5b",
                                  "falcon_mamba_7b", "qwen2_moe_a2_7b"])
@pytest.mark.slow
def test_prefill_then_decode_matches_forward(arch):
    """prefill(prompt) + decode(next) must agree with a full forward
    over prompt+next — the KV-cache/state plumbing correctness test."""
    cfg = get_smoke_config(arch)
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    P = 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, P + 1), 0,
                              cfg.vocab).astype(jnp.int32)
    # full forward logits at position P-1 predict token P
    full = m.forward(params, {"tokens": toks})
    logits_full = full[:, P - 1]

    logits_pre, cache = m.prefill(params, {"tokens": toks[:, :P]})
    if arch == "qwen2_moe_a2_7b":
        # MoE capacity-based token dropping depends on the token set, so
        # prefill(P) vs forward(P+1) route differently by design; the
        # exact check is at equal length:
        same = m.forward(params, {"tokens": toks[:, :P]})[:, -1]
        np.testing.assert_allclose(np.asarray(logits_pre[:, -1]),
                                   np.asarray(same), atol=2e-3,
                                   rtol=2e-3)
    else:
        np.testing.assert_allclose(np.asarray(logits_pre[:, -1]),
                                   np.asarray(logits_full),
                                   atol=2e-3, rtol=2e-3)
    if arch in ("falcon_mamba_7b", "qwen2_moe_a2_7b"):
        # ssm: decode continues from state (covered by prefill check);
        # moe: single-token decode routes under capacity C=1 by design
        return

    # pad KV cache to a larger ring and decode one more token
    W = 16
    pad = W - cache["k"].shape[2]
    cache = {k: (jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                 if k in ("k", "v") else v) for k, v in cache.items()}
    logits_dec, _ = m.decode_step(params, cache, toks[:, P:P + 1],
                                  jnp.int32(P))
    full_next = m.forward(params, {"tokens": toks})[:, P]
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(full_next), atol=2e-3,
                               rtol=2e-3)


def test_sliding_window_ring_buffer():
    """Ring-buffer decode: only the last ``window`` tokens attend."""
    from repro.models.layers import init_kv_cache, update_kv_cache
    cache = init_kv_cache(1, 4, 1, 8, jnp.float32)
    for pos in range(7):
        k = jnp.full((1, 1, 1, 8), float(pos))
        cache, valid = update_kv_cache(cache, k, k, jnp.int32(pos))
    # after 7 inserts into window 4: positions 3..6 valid
    assert bool(jnp.all(valid))
    slots = np.asarray(cache["k"][0, :, 0, 0])
    assert sorted(slots.tolist()) == [3.0, 4.0, 5.0, 6.0]


def test_input_specs_cover_all_shapes():
    for arch in ARCHS:
        cfg = get_config(arch)
        m = get_model(cfg)
        for name, shape in INPUT_SHAPES.items():
            specs = m.input_specs(shape)
            assert specs, (arch, name)
            for leaf in jax.tree_util.tree_leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
