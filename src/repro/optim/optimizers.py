"""Minimal optax-shaped optimizer library (optax is not available offline).

``Optimizer`` is an (init, update) pair; ``update`` maps
(grads, state, params, step) -> (new_params, new_state).  The paper's
local training recipe is SGD(lr=0.01, momentum=0.5); AdamW is provided
for the LM-scale configs.  Momentum/Adam moments can be stored in a
reduced dtype (``state_dtype``) — the memory knob used by the 405B
roofline fit (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.utils import trees


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def constant_schedule(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_schedule(lr: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * jnp.where(step < warmup, warm, cos)
    return fn


def clip_by_global_norm(grads, max_norm: float):
    norm = trees.tree_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return trees.tree_scale(grads, scale), norm


def sgd(lr=0.01, momentum: float = 0.0, weight_decay: float = 0.0,
        state_dtype=None) -> Optimizer:
    """SGD with (optional) heavy-ball momentum — the paper's client recipe."""
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": trees.tree_zeros_like(
            params, dtype=state_dtype)}

    def update(grads, state, params, step):
        lr_t = sched(step)
        if momentum != 0.0:
            m = trees.tree_map(
                lambda m, g: (momentum * m.astype(jnp.float32)
                              + g.astype(jnp.float32)).astype(m.dtype),
                state["m"], grads)
            delta = m
            state = {"m": m}
        else:
            delta = grads
        new_params = trees.tree_map(
            lambda p, d: (p.astype(jnp.float32)
                          - lr_t * (d.astype(jnp.float32)
                                    + weight_decay * p.astype(jnp.float32))
                          ).astype(p.dtype),
            params, delta)
        return new_params, state

    return Optimizer(init, update)


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay: float = 0.0,
          state_dtype=None) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        z = lambda: trees.tree_zeros_like(params, dtype=state_dtype)  # noqa: E731
        return {"m": z(), "v": z()}

    def update(grads, state, params, step):
        lr_t = sched(step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        m = trees.tree_map(
            lambda m, g: (b1 * m.astype(jnp.float32)
                          + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype),
            state["m"], grads)
        v = trees.tree_map(
            lambda v, g: (b2 * v.astype(jnp.float32)
                          + (1 - b2) * jnp.square(g.astype(jnp.float32))
                          ).astype(v.dtype),
            state["v"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m_, v_):
            mh = m_.astype(jnp.float32) / bc1
            vh = v_.astype(jnp.float32) / bc2
            step_ = mh / (jnp.sqrt(vh) + eps) + weight_decay * \
                p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * step_).astype(p.dtype)

        new_params = trees.tree_map(upd, params, m, v)
        return new_params, {"m": m, "v": v}

    return Optimizer(init, update)
