"""Benchmark persistence + regression-gate tooling.

Covers the atomic-write temp-file cleanup in ``benchmarks.common`` and
``tools/check_bench_regression.py`` (pass, injected slowdown,
--update-baseline round trip)."""
import importlib.util
import json
import os
import pathlib
import sys

import pytest

_ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(_ROOT) not in sys.path:          # `benchmarks` is a root package
    sys.path.insert(0, str(_ROOT))

from benchmarks import common  # noqa: E402


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_bench_regression",
        _ROOT / "tools" / "check_bench_regression.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# --------------------------------------------------------------------------
# benchmarks.common.persist_rows
# --------------------------------------------------------------------------
def test_persist_rows_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    rows = [{"name": "s/a", "us_per_call": 120, "derived": ""}]
    path = common.persist_rows("tsuite", rows, quick=True)
    with open(path) as f:
        data = json.load(f)
    assert data["suite"] == "tsuite"
    assert data["runs"][-1]["rows"] == rows
    common.persist_rows("tsuite", rows, quick=False)
    with open(path) as f:
        assert len(json.load(f)["runs"]) == 2
    assert not os.path.exists(path + ".tmp")


def test_persist_rows_cleans_tmp_on_failure(tmp_path, monkeypatch):
    """A failed dump (unserialisable row) must propagate AND leave no
    half-written ``*.tmp`` file behind."""
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    good = [{"name": "s/a", "us_per_call": 100, "derived": ""}]
    path = common.persist_rows("tsuite", good, quick=True)
    with pytest.raises(TypeError):
        common.persist_rows("tsuite", [{"name": "s/b",
                                        "us_per_call": object()}],
                            quick=True)
    assert not os.path.exists(path + ".tmp")
    with open(path) as f:                   # prior trajectory intact
        assert len(json.load(f)["runs"]) == 1


# --------------------------------------------------------------------------
# tools/check_bench_regression.py
# --------------------------------------------------------------------------
def _write_bench(dirpath, suite, rows):
    with open(os.path.join(dirpath, f"BENCH_{suite}.json"), "w") as f:
        json.dump({"suite": suite,
                   "runs": [{"timestamp": "t", "quick": False,
                             "rows": rows}]}, f)


def _row(name, us):
    return {"name": name, "us_per_call": us, "derived": ""}


def test_gate_passes_within_threshold(tmp_path):
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_row("foo/x", 1100)])
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps({"foo": {"foo/x": 1000}}))
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc == 0                          # +10% < 15% threshold


def test_gate_fails_on_injected_slowdown(tmp_path):
    """A 20% slowdown against the baseline exits non-zero (acceptance
    criterion)."""
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_row("foo/x", 1200),
                                   _row("foo/y", 500)])
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps({"foo": {"foo/x": 1000,
                                            "foo/y": 500}}))
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc != 0


def test_gate_skips_sub_minimum_rows(tmp_path):
    """µs-scale rows (dispatch jitter) never trip the gate."""
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_row("foo/tiny", 80)])
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps({"foo": {"foo/tiny": 40}}))
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc == 0                          # 2x but below --min-us


def test_gate_catches_blowup_from_tiny_baseline(tmp_path):
    """A tiny baseline row exploding past --min-us still fails — the
    jitter skip needs BOTH sides small."""
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_row("foo/tiny", 40000)])
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps({"foo": {"foo/tiny": 40}}))
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc == 1


def test_gate_ignores_quick_runs(tmp_path):
    """--quick runs shrink workloads without renaming rows, so they
    are never gated (or baselined) unless --allow-quick."""
    chk = _load_checker()
    with open(os.path.join(tmp_path, "BENCH_foo.json"), "w") as f:
        json.dump({"suite": "foo",
                   "runs": [{"timestamp": "t0", "quick": False,
                             "rows": [_row("foo/x", 1000)]},
                            {"timestamp": "t1", "quick": True,
                             "rows": [_row("foo/x", 9000)]}]}, f)
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps({"foo": {"foo/x": 1000}}))
    # latest run is quick (9x slower) but the gate reads the newest
    # FULL run, which matches the baseline
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc == 0
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline), "--allow-quick"])
    assert rc == 1


def test_gate_new_rows_not_gated(tmp_path):
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_row("foo/x", 1000),
                                   _row("foo/new", 9999)])
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps({"foo": {"foo/x": 1000}}))
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc == 0


def test_update_baseline_roundtrip(tmp_path):
    """--update-baseline rewrites the baseline so the same bench files
    then gate clean — and a later slowdown against it fails."""
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_row("foo/x", 2000)])
    baseline = tmp_path / "baselines.json"
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline), "--update-baseline"])
    assert rc == 0
    assert json.loads(baseline.read_text()) == {"foo": {"foo/x": 2000}}
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc == 0
    _write_bench(tmp_path, "foo", [_row("foo/x", 2400)])  # +20%
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc == 1


def test_explicit_suite_missing_bench_file_fails(tmp_path):
    """A suite NAMED on the command line with no bench run must fail —
    a drifted CI step must not make the gate silently vacuous."""
    chk = _load_checker()
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps({"foo": {"foo/x": 1000}}))
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline), "--suites", "foo"])
    assert rc == 1


def test_no_suites_discovered_is_not_a_failure(tmp_path):
    """With no --suites and an empty bench dir there is nothing to
    gate — not an error."""
    chk = _load_checker()
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(tmp_path / "baselines.json")])
    assert rc == 0


# --------------------------------------------------------------------------
# peak_bytes: rows carrying a memory metric are gated on BOTH axes
# --------------------------------------------------------------------------
def _prow(name, us, peak):
    return {"name": name, "us_per_call": us, "derived": "",
            "peak_bytes": peak}


def test_common_row_carries_peak_bytes_only_when_given():
    common.drain_rows()
    common.row("s/time_only", 100, "")
    common.row("s/with_peak", 100, "", peak_bytes=2 ** 20)
    rows = common.drain_rows()
    assert "peak_bytes" not in rows[0]
    assert rows[1]["peak_bytes"] == 2 ** 20


def test_load_latest_rows_mixed_shapes(tmp_path):
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_row("foo/t", 500),
                                   _prow("foo/m", 600, 1 << 20)])
    rows = chk.load_latest_rows(
        os.path.join(tmp_path, "BENCH_foo.json"))
    assert rows["foo/t"] == 500
    assert rows["foo/m"] == {"us_per_call": 600,
                             "peak_bytes": 1 << 20}


def test_gate_fails_on_peak_regression(tmp_path):
    """+20% peak_bytes with flat wall-clock fails the gate exactly
    like a slowdown."""
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_prow("foo/m", 1000, 1200)])
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps(
        {"foo": {"foo/m": {"us_per_call": 1000,
                           "peak_bytes": 1000}}}))
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc == 1


def test_gate_peak_within_threshold_passes(tmp_path):
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_prow("foo/m", 1000, 1100)])
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps(
        {"foo": {"foo/m": {"us_per_call": 1000,
                           "peak_bytes": 1000}}}))
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc == 0                          # +10% < 15% threshold


def test_gate_peak_not_gated_against_legacy_int_baseline(tmp_path):
    """A row that newly grew a peak_bytes metric against a time-only
    (legacy int) baseline is gated on time alone — no phantom memory
    regression until the baseline records a peak."""
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_prow("foo/m", 1000, 9 << 30)])
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps({"foo": {"foo/m": 1000}}))
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc == 0


def test_update_baseline_preserves_row_shapes(tmp_path):
    """--update-baseline writes dict rows where peak_bytes exists and
    keeps the legacy plain-int shape everywhere else — then gates
    clean against itself."""
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_row("foo/t", 2000),
                                   _prow("foo/m", 3000, 1 << 20)])
    baseline = tmp_path / "baselines.json"
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline), "--update-baseline"])
    assert rc == 0
    assert json.loads(baseline.read_text()) == {
        "foo": {"foo/t": 2000,
                "foo/m": {"us_per_call": 3000,
                          "peak_bytes": 1 << 20}}}
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc == 0


# --------------------------------------------------------------------------
# --check-registered: PERF_SUITES registry vs baseline entries
# --------------------------------------------------------------------------
def _write_registry(tmp_path, suites):
    reg = tmp_path / "run.py"
    reg.write_text("SUITES = {}\nPERF_SUITES = "
                   + json.dumps(suites) + "\n")
    return reg


def test_registered_suite_without_baseline_fails(tmp_path):
    """A suite registered in run.py's PERF_SUITES with NO baseline
    entry fails the gate with a clear message — the drift where a new
    bench suite lands but its baseline never gets committed."""
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_row("foo/x", 1000)])
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps({"foo": {"foo/x": 1000}}))
    reg = _write_registry(tmp_path, ["foo", "newsuite"])
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline),
                   "--check-registered", "--registry", str(reg)])
    assert rc == 1
    # with every registered suite baselined, the same gate passes
    baseline.write_text(json.dumps({"foo": {"foo/x": 1000},
                                    "newsuite": {}}))
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline),
                   "--check-registered", "--registry", str(reg)])
    assert rc == 0


def test_registered_check_is_opt_in(tmp_path):
    """Without --check-registered, a missing baseline entry for a
    registered suite does not fail (scratch-baseline workflows)."""
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_row("foo/x", 1000)])
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps({"foo": {"foo/x": 1000}}))
    _write_registry(tmp_path, ["foo", "newsuite"])
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline)])
    assert rc == 0


def test_registered_check_missing_registry_is_noop(tmp_path):
    chk = _load_checker()
    _write_bench(tmp_path, "foo", [_row("foo/x", 1000)])
    baseline = tmp_path / "baselines.json"
    baseline.write_text(json.dumps({"foo": {"foo/x": 1000}}))
    rc = chk.main(["--bench-dir", str(tmp_path),
                   "--baseline", str(baseline), "--check-registered",
                   "--registry", str(tmp_path / "nope.py")])
    assert rc == 0


def test_repo_registry_parses_and_baselines_complete():
    """The real benchmarks/run.py PERF_SUITES parses via ast and every
    registered perf suite carries a committed baseline entry — the
    in-repo invariant the CI flag enforces."""
    chk = _load_checker()
    suites = chk.registered_perf_suites(str(_ROOT / "benchmarks"
                                            / "run.py"))
    assert "stacked_agg" in suites and "kernels" in suites
    with open(_ROOT / "benchmarks" / "baselines.json") as f:
        baseline = json.load(f)
    assert not set(suites) - set(baseline), (
        "registered perf suites missing baselines: "
        f"{set(suites) - set(baseline)}")
