# MA-Echo — the paper's primary contribution.
from repro.core.maecho import MAEchoConfig, maecho_aggregate  # noqa: F401
from repro.core.projections import (  # noqa: F401
    projection_from_features, null_projector_from_features,
    projection_direct, block_update, owm_update, svd_compress, svd_restore,
)
from repro.core.qp import solve_qp, project_capped_simplex  # noqa: F401
from repro.core.aggregators import AGGREGATORS, fedavg  # noqa: F401
