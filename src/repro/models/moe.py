"""Mixture-of-Experts transformer (qwen2-moe / grok-1 families).

The MoE block replaces the dense MLP; attention/embedding/decode logic
is reused from ``repro.models.dense``.  Dispatch is **grouped**: tokens
are processed in groups of ``moe.group_size`` with a per-group capacity
``C = ceil(top_k * g / E * capacity_factor)``, Switch-style one-hot
dispatch/combine tensors, so dispatch FLOPs stay O(g·E·C·d) per group
instead of O(T·E·T·d) globally.  (A sort-based ragged dispatch is the
§Perf hillclimb alternative.)

Expert weights are tensor-parallel (d_ff sharded over the ``model``
axis) because neither 60 nor 8 experts divide the 16-way model axis —
see DESIGN.md §5; the expert-parallel variant for grok (8 | mesh
reshape) is a recorded perf experiment.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import dense
from repro.models import layers as L
from repro.models.config import ModelConfig


def moe_init(rng, cfg: ModelConfig, n_layers: int):
    m = cfg.moe
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 5)

    def expert_stack(k, d_in, d_out):
        kk = jax.random.split(k, n_layers * m.n_experts)
        w = jnp.stack([L.dense_init(q, d_in, d_out, cfg.pdtype) for q in kk])
        return w.reshape(n_layers, m.n_experts, d_in, d_out)

    p = {
        "router": dense._stacked(ks[0], n_layers, d, m.n_experts, cfg),
        "we_gate": expert_stack(ks[1], d, f),
        "we_up": expert_stack(ks[2], d, f),
        "we_down": expert_stack(ks[3], f, d),
    }
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["ws_gate"] = dense._stacked(kk[0], n_layers, d, fs, cfg)
        p["ws_up"] = dense._stacked(kk[1], n_layers, d, fs, cfg)
        p["ws_down"] = dense._stacked(kk[2], n_layers, fs, d, cfg)
    return p


def init_params(cfg: ModelConfig, rng):
    k1, k2 = jax.random.split(rng)
    params = dense.init_params(cfg.replace(family="dense"), k1)
    layer_p = params["layers"]
    for key in ("w_gate", "w_up", "w_down"):
        del layer_p[key]
    layer_p.update(moe_init(k2, cfg, cfg.n_layers))
    return params


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------
def _route(lp, xg, cfg: ModelConfig):
    """xg: (N, g, d) grouped tokens.  Returns dispatch/combine tensors.

    dispatch: (N, g, E, C) float {0,1};  combine: (N, g, E, C) float.
    """
    m = cfg.moe
    N, g, d = xg.shape
    E = m.n_experts
    C = max(1, math.ceil(m.top_k * g / E * m.capacity_factor))

    logits = (xg @ lp["router"].astype(cfg.cdtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (N, g, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)      # (N, g, k)
    # renormalise the selected gates
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (N, g, k, E)
    # position of each (token, choice) within its expert queue
    flat = onehot.reshape(N, g * m.top_k, E)
    pos = jnp.cumsum(flat, axis=1) * flat - 1.0              # (N, g*k, E)
    pos = pos.reshape(N, g, m.top_k, E)
    keep = (pos >= 0) & (pos < C)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, -1).astype(jnp.int32), C,
                            dtype=jnp.float32)               # (N, g, k, E, C)
    dispatch = jnp.sum(pos_oh, axis=2)                       # (N, g, E, C)
    combine = jnp.sum(pos_oh * gate_vals[..., None, None], axis=2)

    # aux losses (Switch-style)
    me = jnp.mean(probs, axis=1)                             # (N, E)
    ce = jnp.mean(onehot.sum(2), axis=1)                     # fraction routed
    lb_loss = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = m.load_balance_loss * lb_loss + m.router_z_loss * z_loss
    return dispatch, combine, aux


def _route_gather(lp, xg, cfg: ModelConfig):
    """Scatter/gather routing — identical semantics to :func:`_route`
    (same top-k, same capacity-order token dropping) with ZERO matmul
    FLOPs in dispatch/combine.  Returns (xe (N,E,C,d) expert inputs,
    combine_fn(ye) -> (N,g,d), aux)."""
    m = cfg.moe
    N, g, d = xg.shape
    E = m.n_experts
    C = max(1, math.ceil(m.top_k * g / E * m.capacity_factor))

    logits = (xg @ lp["router"].astype(cfg.cdtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)      # (N,g,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)
    flat = onehot.reshape(N, g * m.top_k, E)
    pos = jnp.cumsum(flat, axis=1) * flat - 1.0
    slot = jnp.sum(pos.reshape(N, g, m.top_k, E) * onehot,
                   axis=-1).astype(jnp.int32)                # (N,g,k)
    keep = (slot >= 0) & (slot < C)
    slot_c = jnp.clip(slot, 0, C - 1)

    def scatter_one(xg_n, eidx_n, slot_n, keep_n):
        # xg_n (g,d); choice streams flattened (g*k,)
        tok = jnp.repeat(jnp.arange(g), m.top_k)
        e = eidx_n.reshape(-1)
        s = slot_n.reshape(-1)
        k_mask = keep_n.reshape(-1)
        vals = xg_n[tok] * k_mask[:, None].astype(xg_n.dtype)
        xe = jnp.zeros((E, C, xg_n.shape[-1]), xg_n.dtype)
        return xe.at[e, s].add(vals)

    xe = jax.vmap(scatter_one)(xg, gate_idx, slot_c, keep)

    def combine_fn(ye):
        def gather_one(ye_n, eidx_n, slot_n, keep_n, gv_n):
            e = eidx_n.reshape(-1)
            s = slot_n.reshape(-1)
            w = (gv_n.reshape(-1) * keep_n.reshape(-1)
                 ).astype(ye_n.dtype)
            vals = ye_n[e, s] * w[:, None]                   # (g*k, d)
            return vals.reshape(g, m.top_k, -1).sum(axis=1)

        return jax.vmap(gather_one)(ye, gate_idx, slot_c, keep,
                                    gate_vals)

    me = jnp.mean(probs, axis=1)
    ce = jnp.mean(onehot.sum(2), axis=1)
    lb_loss = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = m.load_balance_loss * lb_loss + m.router_z_loss * z_loss
    return xe, combine_fn, aux


def moe_block(lp, x, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    g = min(m.group_size, T)
    pad = (-T) % g
    xf = jnp.pad(x.reshape(T, d), ((0, pad), (0, 0)))
    N = xf.shape[0] // g
    xg = xf.reshape(N, g, d)
    dt = cfg.cdtype

    if m.dispatch_mode == "gather":
        xe, combine_fn, aux = _route_gather(lp, xg, cfg)
        xe = xe.astype(dt)
        h = jax.nn.silu(jnp.einsum("necd,edf->necf", xe,
                                   lp["we_gate"].astype(dt)))
        h = h * jnp.einsum("necd,edf->necf", xe,
                           lp["we_up"].astype(dt))
        ye = jnp.einsum("necf,efd->necd", h, lp["we_down"].astype(dt))
        y = combine_fn(ye)
    else:
        dispatch, combine, aux = _route(lp, xg, cfg)
        xe = jnp.einsum("ngec,ngd->necd", dispatch.astype(dt), xg)
        h = jax.nn.silu(jnp.einsum("necd,edf->necf", xe,
                                   lp["we_gate"].astype(dt)))
        h = h * jnp.einsum("necd,edf->necf", xe, lp["we_up"].astype(dt))
        ye = jnp.einsum("necf,efd->necd", h, lp["we_down"].astype(dt))
        y = jnp.einsum("ngec,necd->ngd", combine.astype(dt), ye)
    y = y.reshape(-1, d)[:T].reshape(B, S, d)

    if m.n_shared_experts:
        y = y + L.swiglu(x, lp["ws_gate"].astype(dt), lp["ws_up"].astype(dt),
                         lp["ws_down"].astype(dt))
    return y, aux


# --------------------------------------------------------------------------
# model API (reuses dense forward with an mlp hook; aux loss via side sum)
# --------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, batch, collect_aux: bool = False):
    x, positions = dense.embed_inputs(cfg, params, batch)

    def body(carry, lp):
        x, aux = carry
        h = x + dense.attn_block(
            lp, L.rms_norm(x, lp["ln1"], cfg.norm_eps), positions, cfg)
        y, a = moe_block(lp, L.rms_norm(h, lp["ln2"], cfg.norm_eps), cfg)
        return (h + y, aux + a), None

    body_ = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_, (x, jnp.float32(0.0)),
                               params["layers"], unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.cdtype)
    logits = x @ head
    return (logits, aux) if collect_aux else logits


def loss_fn(cfg: ModelConfig, params, batch):
    logits, aux = forward(cfg, params, batch, collect_aux=True)
    return L.softmax_xent(logits, batch["labels"],
                          batch.get("loss_mask")) + aux


init_cache = dense.init_cache


def prefill(cfg: ModelConfig, params, batch):
    return dense.prefill(cfg, params, batch,
                         mlp_fn=lambda lp, y: moe_block(lp, y, cfg)[0])


def decode_step(cfg: ModelConfig, params, cache, token, position, *,
                w_live: int | None = None):
    return dense.decode_step(
        cfg, params, cache, token, position,
        mlp_fn=lambda lp, y: moe_block(lp, y, cfg)[0], w_live=w_live)
