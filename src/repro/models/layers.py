"""Shared neural-net building blocks (pure functions on param dicts).

Everything here is jit/scan/vmap-friendly and shape-polymorphic over
batch/sequence.  Attention is implemented flash-style (chunked online
softmax) in pure jnp so that 32k-sequence prefill lowers with O(S·chunk)
activation memory; the Pallas kernel in ``repro.kernels.flash_attention``
is the TPU-target version of the same computation and is validated
against :func:`chunked_attention` as its oracle.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------
# initialisers
# --------------------------------------------------------------------------
def dense_init(rng, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out)) * scale).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype):
    return (jax.random.normal(rng, (vocab, d)) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rms_norm(x, gamma, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * gamma.astype(dt)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * gamma.astype(dt) + beta.astype(dt)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S) int32."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention — chunked online-softmax (training / prefill)
# --------------------------------------------------------------------------
def _repeat_kv(k, n_rep: int):
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D)."""
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def chunked_attention(
    q, k, v, *, causal: bool = True, q_chunk: int = 512, k_chunk: int = 1024,
    q_offset=0, unroll: bool = False,
):
    """Flash-style attention in pure jnp.

    q: (B, Sq, Hq, D);  k, v: (B, Sk, Hkv, D) with Hq % Hkv == 0.
    ``q_offset`` is the absolute position of q[0] (for prefill-with-cache).
    Returns (B, Sq, Hq, D).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    n_rep = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    if q_chunk >= Sq and k_chunk >= Sk:
        # single-chunk fast path (also used by the roofline probe
        # lowerings, which must avoid while-loops for exact HLO costs)
        k_r = _repeat_kv(k, n_rep)
        v_r = _repeat_kv(v, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k_r,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = q_offset + jnp.arange(Sq)
            mask = q_pos[:, None] >= jnp.arange(Sk)[None, :]
            s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v_r.dtype), v_r,
                         preferred_element_type=jnp.float32)
        return out.astype(q.dtype)
    # pad to multiples
    pq = (-Sq) % q_chunk
    pk = (-Sk) % k_chunk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // k_chunk

    qp = qp.reshape(B, nq, q_chunk, Hq, D)
    kp = kp.reshape(B, nk, k_chunk, Hkv, D)
    vp = vp.reshape(B, nk, k_chunk, Hkv, D)

    q_pos = (q_offset + jnp.arange(nq * q_chunk)).reshape(nq, q_chunk)
    k_pos = jnp.arange(nk * k_chunk).reshape(nk, k_chunk)
    k_valid = (jnp.arange(nk * k_chunk) < Sk).reshape(nk, k_chunk)

    def q_block(qi, q_c):
        # q_c: (B, q_chunk, Hq, D)
        qpos = q_pos[qi]                                     # (q_chunk,)

        def kv_step(carry, inputs):
            acc, m, l = carry
            k_c, v_c, kpos, kval = inputs
            k_r = _repeat_kv(k_c, n_rep)                     # (B, kc, Hq, D)
            v_r = _repeat_kv(v_c, n_rep)
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", q_c, k_r,
                preferred_element_type=jnp.float32) * scale  # (B,Hq,qc,kc)
            mask = kval[None, :]
            if causal:
                mask = mask & (qpos[:, None] >= kpos[None, :])
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))      # (B,Hq,qc)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v_r.dtype), v_r,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, Hq, q_chunk, D), jnp.float32)
        m0 = jnp.full((B, Hq, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hq, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kp.transpose(1, 0, 2, 3, 4), vp.transpose(1, 0, 2, 3, 4),
             k_pos, k_valid), unroll=nk if unroll else 1)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3)                     # (B, qc, Hq, D)

    if unroll:
        outs = jnp.stack([q_block(i, qp[:, i]) for i in range(nq)])
    else:
        outs = jax.lax.map(lambda args: q_block(*args),
                           (jnp.arange(nq), qp.transpose(1, 0, 2, 3, 4)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, Hq, D)
    return out[:, :Sq].astype(q.dtype)


def prefill_attention(q, k, v, *, causal: bool = True, q_chunk: int = 512,
                      k_chunk: int = 1024, q_offset=0, unroll: bool = False,
                      backend: str = "auto"):
    """Prefill/train attention with backend dispatch (same contract as
    :func:`chunked_attention`).

    ``backend`` (``ModelConfig.attn_backend``): "oracle" always runs the
    jnp chunked path; "kernel" forces the Pallas flash kernel whenever
    the shape is expressible (warn-once fallback otherwise); "auto"
    takes the kernel on compiled (non-interpret) runs — under the CPU
    interpreter the scanned kernel body always loses to fused jnp, so
    auto stays on the oracle there.  Eligible shapes: causal
    self-attention with Sq == Sk and no query offset (both sequences
    zero-pad to a block multiple exactly — padded keys are causally
    masked for every real query), or non-causal with Sk already a block
    multiple (zero-padded keys would enter the softmax; query rows
    pad/crop freely).  Sharded tracing (shard_ctx active) stays on the
    oracle, whose GSPMD layout is tuned (§Perf H4).
    """
    from repro.kernels import ops
    from repro.sharding import ctx as shard_ctx

    want_kernel = backend == "kernel" or (
        backend == "auto" and not ops.interpret_default())
    if want_kernel and not shard_ctx.active():
        Sq, Sk = q.shape[1], k.shape[1]
        offset_free = isinstance(q_offset, int) and q_offset == 0
        eligible = ((causal and Sq == Sk and offset_free)
                    or (not causal and Sk % ops.DEFAULT_BLOCK == 0))
        if eligible:
            return ops.flash_attention_auto(q, k, v, causal=causal)
        if backend == "kernel":
            ops.fallback_warn(
                f"prefill attention (Sq={Sq}, Sk={Sk}, causal={causal}, "
                f"q_offset={q_offset}) not expressible by the flash "
                f"kernel: running the jnp chunked oracle")
    return chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                             k_chunk=k_chunk, q_offset=q_offset,
                             unroll=unroll)


def decode_attention(q, k_cache, v_cache, valid_mask, *,
                     backend: str = "auto", w_live: int | None = None):
    """Single-token attention against a (possibly ring-buffer) KV cache,
    with backend dispatch.

    q: (B, 1, Hq, D); caches: (B, W, Hkv, D); valid_mask: (B, W) bool.
    ``backend`` (``ModelConfig.attn_backend``): "oracle" forces the
    dense full-window einsum; "kernel" forces the Pallas window kernel
    whenever W divides a block (warn-once fallback otherwise); "auto"
    takes the kernel when the window is blocked AND spans at least two
    blocks, where skipping invalid window blocks pays for the launch.
    Sharded decode (shard_ctx active) always runs the oracle — its
    GSPMD cache pinning is tuned there (§Perf H2).

    ``w_live`` is the serving loop's static upper bound on written
    ring-buffer slots (see ``ops.decode_attention_auto``): the kernel
    path crops the cache read to the live bucket.  The oracle path
    ignores it — backend="oracle" is the pristine pre-kernel full-window
    einsum, which is what the serve benchmark baselines.
    """
    from repro.sharding import ctx as shard_ctx

    if backend != "oracle" and not shard_ctx.active():
        from repro.kernels import ops

        W = k_cache.shape[1]
        blocked = W % ops.DEFAULT_BLOCK == 0
        # "auto" under the CPU interpreter needs the crop to win (the
        # grid scan re-copies the carried cache every step); compiled
        # runs take the kernel whenever the window spans ≥ 2 blocks
        wins = W >= 2 * ops.DEFAULT_BLOCK and (
            not ops.interpret_default() or w_live is not None)
        if blocked and (backend == "kernel" or wins):
            return ops.decode_attention_auto(q, k_cache, v_cache,
                                             valid_mask, w_live=w_live)
        if backend == "kernel":
            ops.fallback_warn(
                f"decode window W={W} is not a {ops.DEFAULT_BLOCK}-"
                f"multiple: running the dense jnp decode oracle")
    return decode_attention_oracle(q, k_cache, v_cache, valid_mask)


def decode_attention_oracle(q, k_cache, v_cache, valid_mask):
    """Dense full-window decode attention (the jnp oracle: one einsum
    over all W slots regardless of fill).

    q: (B, 1, Hq, D); caches: (B, W, Hkv, D); valid_mask: (B, W) bool.
    """
    from repro.sharding import ctx as shard_ctx

    B, _, Hq, D = q.shape
    _, W, Hkv, _ = k_cache.shape
    n_rep = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    # grouped-GQA form: contract against the cache directly — no
    # repeat_kv materialisation (whose broadcast forced GSPMD into a
    # full cache reshard on the hd-sharded layout; §Perf H2)
    qg = q.reshape(B, 1, Hkv, n_rep, D)
    # pin q's hd to the cache's sharded layout: forces a partial
    # contraction + scores-AR instead of a 1 GB K gather (§Perf H2)
    qg = shard_ctx.constrain_lastdim(qg)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    # replicate the (small) scores: partial-contraction + AR beats
    # all-gathering the hd-sharded cache
    s = shard_ctx.constrain_scores(s)
    s = jnp.where(valid_mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# --------------------------------------------------------------------------
# KV cache (ring buffer for sliding-window long-context decode)
# --------------------------------------------------------------------------
def init_kv_cache(batch: int, window: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((batch, window, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, window, n_kv, head_dim), dtype),
    }


def update_kv_cache(cache, k_new, v_new, position):
    """Insert one token per row at ``position % window`` (ring buffer).

    k_new/v_new: (B, 1, Hkv, D); position: scalar int32 (every row at
    the same absolute position — the lockstep fixed-batch loop) or (B,)
    int32 per-row positions (the continuous-batching slot loop, where
    each slot decodes at its own depth).  Returns
    (cache, valid_mask (B, W)).
    """
    from repro.sharding import ctx as shard_ctx

    B, W = cache["k"].shape[0], cache["k"].shape[1]
    position = jnp.asarray(position, jnp.int32)
    # pin cache sharding across the update (EXPERIMENTS.md §Perf H2:
    # GSPMD otherwise fully rematerialises the cache — 1.1 GB AG/layer)
    k_new = shard_ctx.constrain_cache(k_new, "k")
    v_new = shard_ctx.constrain_cache(v_new, "v")
    kc = shard_ctx.constrain_cache(cache["k"], "k")
    vc = shard_ctx.constrain_cache(cache["v"], "v")
    idx = jnp.arange(W)
    if position.ndim == 0:
        slot = jnp.mod(position, W)
        k = jax.lax.dynamic_update_slice_in_dim(kc, k_new, slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(vc, v_new, slot, axis=1)
        pos = position[None]                                  # (1,) rows
    else:
        # per-row slots: one-hot where-write (a batched DUS would lower
        # to a gather/scatter pair; the select keeps the cache in place)
        hit = idx[None, :] == jnp.mod(position, W)[:, None]   # (B, W)
        k = jnp.where(hit[:, :, None, None], k_new, kc)
        v = jnp.where(hit[:, :, None, None], v_new, vc)
        pos = position
    k = shard_ctx.constrain_cache(k, "k")
    v = shard_ctx.constrain_cache(v, "v")
    # slot i holds absolute position p with p % W == i and p <= position;
    # valid iff that p > position - W  (within window) and p >= 0.
    pos = pos[:, None]
    last_abs = pos - jnp.mod(pos - idx[None, :], W)  # latest abs pos per slot
    valid = (last_abs >= 0) & (last_abs > pos - W)
    valid = jnp.broadcast_to(valid, (B, W))
    return {"k": k, "v": v}, valid


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jax.nn.gelu(x @ w_in + b_in, approximate=True)
    return h @ w_out + b_out


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------
def softmax_xent(logits, labels, mask=None):
    """Mean token-level cross entropy; labels (…,) int32; mask same shape."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
