"""Quickstart: one-shot federated aggregation with MA-Echo.

Two clients train MLPs on disjoint halves of a 10-class problem
(Dirichlet beta=0.01 -> almost no label overlap), then the server
aggregates WITHOUT any training or public data, exactly the paper's
setting.  Compare: local models / FedAvg / OT matching / MA-Echo /
ensemble.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.maecho import MAEchoConfig
from repro.data.partition import dirichlet_partition, partition_stats
from repro.data.synthetic import MNIST_LIKE, generate
from repro.fl import models as pm
from repro.fl.client import (LocalTrainConfig, compute_projections,
                             evaluate_classifier, train_classifier)
from repro.fl.server import one_shot_aggregate


def main():
    data = generate(MNIST_LIKE)
    parts = dirichlet_partition(data["train_y"], 2, beta=0.01, seed=0)
    print("label partition (rows = clients):")
    print(partition_stats(data["train_y"], parts))

    spec = pm.MLP_SPEC          # the paper's 784-400-200-100-10 MLP
    clients, projs = [], []
    for k, ix in enumerate(parts):
        params = pm.init(spec, jax.random.PRNGKey(k))  # diff init
        params, _ = train_classifier(
            spec, params, data["train_x"][ix], data["train_y"][ix],
            LocalTrainConfig(epochs=10))               # paper recipe
        acc = evaluate_classifier(spec, params, data["test_x"],
                                  data["test_y"])
        print(f"client {k}: global test acc {acc:.3f}")
        clients.append(params)
        # the one extra forward epoch: per-layer projection matrices
        projs.append(compute_projections(spec, params,
                                         data["train_x"][ix]))

    for method in ("fedavg", "ot", "maecho", "maecho+ot"):
        kw = {"cfg": MAEchoConfig(tau=30, eta=0.5, mu=20.0)} \
            if method.startswith("maecho") else {}
        g = one_shot_aggregate(spec, clients, projs, method, **kw)
        acc = evaluate_classifier(spec, g, data["test_x"],
                                  data["test_y"])
        print(f"{method:12s} -> global acc {acc:.3f}")


if __name__ == "__main__":
    main()
