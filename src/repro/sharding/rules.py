"""Per-architecture sharding rules (DESIGN.md §5).

Mesh axes: optional ``pod`` (2), ``data`` (16), ``model`` (16).
Batch shards over (pod, data); weights shard their feature dims over
``model`` and — when ``cfg.fsdp`` — their other dim over ``data``
(ZeRO-3 style, required for the 314B/405B configs to fit 16 GB v5e).

Every rule passes through :func:`_ok`, which verifies divisibility and
falls back to replication — GSPMD would handle uneven shards with
padding, but even sharding keeps the roofline numbers honest.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.utils import trees


def mesh_axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh_axis_size(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.shape else 1


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def sharded_ok2d(out_d: int, in_d: int, out_asz: int, in_asz: int,
                 block: int = 128, warn: bool = False) -> bool:
    """Eligibility of a leaf for the 2-D (out × in) sharded pipeline.

    Both dims must reach one tile, and *each* dim's tile count must
    divide evenly over its axis group — the same block-granular `_ok`
    divisibility contract as ``kernels.ops.sharded_ok``, applied per
    axis (out-rows over ``cfg.mesh_axis``, in-columns over
    ``cfg.mesh_in_axis``; every device gets whole tiles on both dims).
    This is what lets a leaf whose out-dim alone cannot span the fleet
    (out tiles < device count) still aggregate sharded: the fleet
    factors as out_asz × in_asz and only the per-axis counts must
    divide.  With ``warn=True`` an ineligible leaf surfaces the
    fallback once via ``kernels.ops.fallback_warn`` (the plan compiler
    sets it) instead of degrading silently.
    """
    if out_d < block or in_d < block:
        ok = False
    else:
        ok = ((-(-out_d // block)) % out_asz == 0
              and (-(-in_d // block)) % in_asz == 0)
    if not ok and warn:
        from repro.kernels.ops import fallback_warn

        fallback_warn(
            f"sharded2d-ineligible leaf (out={out_d}, in={in_d}, "
            f"axes={out_asz}x{in_asz}, block={block}): falling back "
            f"to the 1-D out-dim shard / single-device dispatch")
    return ok


class Rules:
    """Builds PartitionSpecs with divisibility checks."""

    def __init__(self, mesh: Mesh, cfg: ModelConfig):
        self.mesh = mesh
        self.cfg = cfg
        self.fsdp_ax = "data" if cfg.fsdp else None

    def _ok(self, dim: int, ax) -> Optional[str]:
        if ax is None:
            return None
        if dim % mesh_axis_size(self.mesh, ax) == 0:
            return ax
        return None

    def spec(self, shape: tuple, axes: tuple) -> P:
        """axes: per-dim axis names (or None); divisibility-checked."""
        assert len(shape) == len(axes), (shape, axes)
        return P(*[self._ok(d, a) for d, a in zip(shape, axes)])

    # ---------------- parameters ----------------
    def param_spec(self, path: str, shape: tuple) -> P:
        cfg = self.cfg
        f = self.fsdp_ax
        rules: list[tuple[str, tuple]] = [
            # embeddings / heads
            (r"(^|\.)embed$", ("model", f)),
            (r"lm_head$", (f, "model")),
            (r"vision_proj$", (None, "model")),
            (r"dec_pos$", (None, None)),
            # attention (stacked: leading L handled by padding below)
            (r"\.w?x?q$|\.wq$", (f, "model")),
            (r"\.wk$|\.wxk$", (f, "model")),
            (r"\.wv$|\.wxv$", (f, "model")),
            (r"\.wo$|\.wxo$", ("model", f)),
            (r"\.b(q|k|v|xq|xv)$", ("model",)),
            (r"\.b(o|xo)$", (None,)),
            # dense mlp
            (r"w_gate$|w_up$|ws_gate$|ws_up$|w_in$", (f, "model")),
            (r"w_down$|ws_down$|w_out$", ("model", f)),
            (r"\.b_in$", ("model",)),
            (r"\.b_out$", (None,)),
            # moe (leading E handled below)
            (r"we_gate$|we_up$", (f, "model")),
            (r"we_down$", ("model", f)),
            (r"router$", (f, None)),
            # mamba
            (r"in_proj$", (f, "model")),
            (r"out_proj$", ("model", f)),
            (r"x_proj$", ("model", None)),
            (r"dt_proj$", (None, "model")),
            (r"conv_w$", ("model", None)),
            (r"A_log$", ("model", None) if cfg.ssm and cfg.ssm.version == 1
             else ("model",)),
            (r"dt_bias$|(^|\.)D$", ("model",)),
            (r"conv_b$", ("model",)),
            # norms / everything 1-D
            (r"ln|norm|gate_norm", (None,)),
        ]
        trailing = trees.first_match(rules, path)
        if trailing is None:
            return P(*([None] * len(shape)))
        lead = len(shape) - len(trailing)
        axes = (None,) * lead + tuple(trailing)
        return self.spec(shape, axes)

    def params_shardings(self, param_specs):
        def mk(path, leaf):
            return NamedSharding(self.mesh,
                                 self.param_spec(path, leaf.shape))
        return trees.map_with_path(mk, param_specs)

    # ---------------- inputs ----------------
    def batch_spec(self, path: str, shape: tuple) -> P:
        da = data_axes(self.mesh)
        key = path.split(".")[-1]
        if key in ("tokens", "labels", "token", "loss_mask"):
            return self.spec(shape, (da,) + (None,) * (len(shape) - 1))
        if key in ("patch_embeds", "audio_embeds"):
            return self.spec(shape, (da,) + (None,) * (len(shape) - 1))
        if key == "position":
            return P()
        return P(*([None] * len(shape)))

    def cache_spec(self, path: str, shape: tuple) -> P:
        """KV / SSM cache sharding for decode.

        KV: (L, B, W, Hkv, hd) — batch over data, heads over model when
        divisible, else head_dim over model.  SSM h: (…, B, di|nh, ds…)
        — inner dim over model.  Hybrid attn cache: (G, B, W, Hkv, hd).
        """
        da = data_axes(self.mesh)
        last = path.split(".")[-1]
        if last in ("k", "v", "xk", "xv"):
            L, B, W, Hkv, hd = shape[-5:] if len(shape) == 5 else (
                (None,) + shape)
            nm = mesh_axis_size(self.mesh, "model")
            if Hkv is not None and Hkv % nm == 0:
                axes = (None, da, None, "model", None)
            else:
                axes = (None, da, None, None, "model")
            return self.spec(shape, axes[-len(shape):])
        if last == "h":          # (L, B, di, ds) or (G, k, B, nh, ds, hd)
            if len(shape) == 4:
                return self.spec(shape, (None, da, "model", None))
            return self.spec(shape, (None, None, da, "model", None, None))
        if last == "conv":       # (L, B, K-1, C) or (G, k, B, K-1, C)
            if len(shape) == 4:
                return self.spec(shape, (None, da, None, "model"))
            return self.spec(shape, (None, None, da, None, "model"))
        return P(*([None] * len(shape)))

    def inputs_shardings(self, input_specs):
        def mk(path, leaf):
            if path.startswith("cache"):
                return NamedSharding(self.mesh,
                                     self.cache_spec(path, leaf.shape))
            return NamedSharding(self.mesh,
                                 self.batch_spec(path, leaf.shape))
        return trees.map_with_path(mk, input_specs)

    # ---------------- one-shot aggregation ----------------
    # PartitionSpecs for the mesh-sharded MA-Echo pipeline
    # (core.maecho backend="sharded"): leaf out-rows split over the
    # data axes, everything that feeds the global QP replicated.  The
    # block-granular eligibility itself lives in kernels.ops.sharded_ok
    # (padding makes the row count exact); these placement rules apply
    # the plain `_ok` divisibility contract for callers that stage the
    # operands onto the mesh ahead of the call.  The shapes must stay
    # congruent with the shard_map specs ops.maecho_sharded_gram/apply
    # build inline (W rows on dim 0, V rows on dim 1, the rest
    # replicated) — pinned by tests/test_sharded_agg.py.
    def agg_out_axes(self, out_dim: int):
        """Axes for a leaf's out-rows — ("pod","data") when the dim
        divides, else None (the single-device fallback)."""
        return self._ok(out_dim, data_axes(self.mesh))

    def agg_weight_spec(self, shape: tuple) -> P:
        """Global weight leaf W (out, in): rows over the data axes.
        1-D bias leaves (oracle path) stay replicated."""
        if len(shape) != 2:
            return P(*([None] * len(shape)))
        return self.spec(shape, (data_axes(self.mesh), None))

    def agg_anchor_spec(self, shape: tuple) -> P:
        """Client-stacked anchors V (N, out, in): the same out-rows on
        axis 1, clients replicated (every device sees all N for the
        pairwise Gram)."""
        if len(shape) != 3:
            return P(*([None] * len(shape)))
        return self.spec(shape, (None, data_axes(self.mesh), None))

    def agg_proj_spec(self, shape: tuple) -> P:
        """Projectors act on the (unsharded) in-axis — replicated."""
        return P(*([None] * len(shape)))

    # ------ 2-D (out × in) aggregation: backend="sharded2d" ------
    # Rows stay on the data axes; the residual's in-columns (and dense
    # projectors' *output* column axis) additionally shard over
    # "model".  Divisibility gating is `sharded_ok2d` above; the
    # shapes must stay congruent with the shard_map specs
    # ops.maecho_sharded2d_gram builds inline (pinned by
    # tests/test_plan.py).
    def agg_in_axes(self, in_dim: int):
        """Axes for a leaf's in-columns — "model" when the dim
        divides, else None (degrades to the 1-D out-row shard)."""
        return self._ok(in_dim, "model")

    def agg_weight_spec2d(self, shape: tuple) -> P:
        """Global weight leaf W (out, in): rows over the data axes AND
        columns over "model" (each with the `_ok` fallback)."""
        if len(shape) != 2:
            return P(*([None] * len(shape)))
        return self.spec(shape, (data_axes(self.mesh), "model"))

    def agg_anchor_spec2d(self, shape: tuple) -> P:
        """Client-stacked anchors V (N, out, in): same 2-D placement
        on the trailing dims, clients replicated."""
        if len(shape) != 3:
            return P(*([None] * len(shape)))
        return self.spec(shape,
                         (None, data_axes(self.mesh), "model"))

    def agg_proj_spec2d(self, shape: tuple) -> P:
        """Dense projectors P (N, in, in): the *output* column axis
        (the last one — the residual's in-index) shards over "model";
        the contraction axis stays replicated (each device contracts
        the full in-dim when forming its residual tile)."""
        if len(shape) != 3:
            return P(*([None] * len(shape)))
        return self.spec(shape, (None, None, "model"))

    def agg_gram_spec(self) -> P:
        """(N, N) Grams are psum-reconstructed — replicated."""
        return P(None, None)

    def agg_alpha_spec(self) -> P:
        """Simplex weights α feed every row shard — replicated."""
        return P(None)


def make_rules(mesh: Mesh, cfg: ModelConfig) -> Rules:
    return Rules(mesh, cfg)
