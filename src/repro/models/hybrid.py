"""Zamba2-style hybrid: mamba2 backbone + shared attention block(s).

arXiv:2411.15242 — a stack of mamba2 layers with a small number of
*shared* (weight-tied) attention blocks invoked periodically.  We scan
over groups: each group applies one shared-attn call followed by
``attn_every`` stacked mamba2 layers; the shared block's weights are
broadcast (not scanned), preserving the weight tying.

MA-Echo applicability: the shared block is a single tensor set —
aggregated once with its own projection; mamba matmuls aggregate per
layer; diagonal SSM params (A_log, D, dt_bias, conv) fall back to
averaging (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import dense, mamba
from repro.models import layers as L
from repro.models.config import ModelConfig


def _n_groups(cfg: ModelConfig) -> int:
    k = cfg.hybrid.attn_every
    assert cfg.n_layers % k == 0, "n_layers must divide by attn_every"
    return cfg.n_layers // k


def init_params(cfg: ModelConfig, rng):
    ks = jax.random.split(rng, 5)
    G, k = _n_groups(cfg), cfg.hybrid.attn_every
    mp = mamba.mamba2_layer_init(ks[1], cfg, cfg.n_layers)
    # reshape stacked L -> (G, k) for the grouped scan
    mp = jax.tree_util.tree_map(
        lambda x: x.reshape(G, k, *x.shape[1:]), mp)
    # the shared block is attention + MLP (zamba2's d_ff lives here)
    shared = {
        "ln1": jnp.ones((cfg.d_model,), cfg.pdtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.pdtype),
        **{key: val[0] for key, val in dense.attn_init(
            ks[2], cfg, 1).items()},
        **{key: val[0] for key, val in dense.mlp_init(
            ks[4], cfg, 1).items()},
    }
    params = {
        "embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.pdtype),
        "mamba": mp,
        "shared_attn": shared,
        "ln_f": jnp.ones((cfg.d_model,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[3], cfg.d_model, cfg.vocab,
                                         cfg.pdtype)
    return params


def forward(cfg: ModelConfig, params, batch):
    x = params["embed"].astype(cfg.cdtype)[batch["tokens"]]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    sp = params["shared_attn"]

    def group(x, gp):
        # shared attention + MLP call (weight-tied across groups)
        x = x + dense.attn_block(
            sp, L.rms_norm(x, sp["ln1"], cfg.norm_eps), positions, cfg)
        x = x + dense.mlp_block(
            sp, L.rms_norm(x, sp["ln2"], cfg.norm_eps), cfg)

        def inner_fn(x, lp):
            y = mamba.mamba2_block(lp, L.rms_norm(x, lp["norm"],
                                                  cfg.norm_eps), cfg)
            return x + y, None

        x, _ = jax.lax.scan(inner_fn, x, gp,
                            unroll=cfg.hybrid.attn_every
                            if cfg.unroll_layers else 1)
        return x, None

    G = _n_groups(cfg)
    group_ = jax.checkpoint(group) if cfg.remat else group
    x, _ = jax.lax.scan(group_, x, params["mamba"],
                        unroll=G if cfg.unroll_layers else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.cdtype)
    return x @ head


def loss_fn(cfg: ModelConfig, params, batch):
    return L.softmax_xent(forward(cfg, params, batch), batch["labels"],
                          batch.get("loss_mask"))


def prefill(cfg: ModelConfig, params, batch):
    """(last_logits, cache): mamba2 final states + shared-attn KV."""
    from repro.models.mamba import causal_conv
    x = params["embed"].astype(cfg.cdtype)[batch["tokens"]]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    sp = params["shared_attn"]

    def group(x, gp):
        h1 = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
        q, k, v = dense._qkv(sp, h1, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o = L.prefill_attention(q, k, v, causal=True,
                                q_chunk=cfg.attn_chunk_q,
                                k_chunk=cfg.attn_chunk_k,
                                unroll=cfg.unroll_layers,
                                backend=cfg.attn_backend)
        x = x + o.reshape(B, S, cfg.n_heads * cfg.hd()) @ \
            sp["wo"].astype(cfg.cdtype)
        x = x + dense.mlp_block(
            sp, L.rms_norm(x, sp["ln2"], cfg.norm_eps), cfg)

        def inner(x, lp):
            y, st = mamba2_block_with_state(
                lp, L.rms_norm(x, lp["norm"], cfg.norm_eps), cfg)
            return x + y, st

        x, mstates = jax.lax.scan(inner, x, gp,
                                  unroll=cfg.hybrid.attn_every
                                  if cfg.unroll_layers else 1)
        return x, (mstates, {"k": k, "v": v})

    G = _n_groups(cfg)
    group_ = jax.checkpoint(group) if cfg.remat else group
    x, (mcache, acache) = jax.lax.scan(group_, x, params["mamba"],
                                       unroll=G if cfg.unroll_layers else 1)
    x = L.rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.cdtype)
    return x @ head, {"mamba": mcache, "attn": acache}


def mamba2_block_with_state(lp, x, cfg: ModelConfig):
    """mamba2_block returning (y, {"h", "conv"}) final state."""
    import repro.models.mamba as mm
    s = cfg.ssm
    dt_ = cfg.cdtype
    B_, S, d = x.shape
    xs, z, Bc, Cc, dt_raw, di, nh = mm._mamba2_split(lp, x, cfg)
    hd = s.head_dim

    xbc_raw = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_tail = xbc_raw[:, -(s.d_conv - 1):, :]
    xbc = mm.causal_conv(xbc_raw, lp["conv_w"].astype(dt_),
                         lp["conv_b"].astype(dt_))
    xbc = jax.nn.silu(xbc)
    xs, Bc, Cc = xbc[..., :di], xbc[..., di:di + s.d_state], \
        xbc[..., di + s.d_state:]

    dt = jax.nn.softplus(dt_raw + lp["dt_bias"].astype(dt_))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32) * A)
    xh = xs.reshape(B_, S, nh, hd).astype(jnp.float32)
    dBx = dt.astype(jnp.float32)[..., None, None] * \
        Bc.astype(jnp.float32)[:, :, None, :, None] * xh[..., None, :]

    def step(h, inputs):
        dA_t, dBx_t, C_t = inputs
        h = dA_t[..., None, None] * h + dBx_t
        y = jnp.einsum("bhsd,bs->bhd", h, C_t)
        return h, y

    if cfg.ssm_assoc:
        dA_b = jnp.broadcast_to(dA[..., None, None], dBx.shape)
        hs = mm._assoc_scan(dA_b, dBx)
        h_fin = hs[:, -1]
        y = jnp.einsum("bthsd,bts->bthd", hs,
                       Cc.astype(jnp.float32))
        y = y.reshape(B_, S, di).astype(dt_)
    else:
        h0 = jnp.zeros((B_, nh, s.d_state, hd), jnp.float32)
        h_fin, ys = jax.lax.scan(
            step, h0,
            (dA.transpose(1, 0, 2), dBx.transpose(1, 0, 2, 3, 4),
             Cc.astype(jnp.float32).transpose(1, 0, 2)))
        y = ys.transpose(1, 0, 2, 3).reshape(B_, S, di).astype(dt_)
    y = y + xs * jnp.repeat(lp["D"].astype(dt_), hd)
    y = L.rms_norm(y * jax.nn.silu(z), lp["gate_norm"], cfg.norm_eps)
    return y @ lp["out_proj"].astype(dt_), \
        {"h": h_fin, "conv": conv_tail}


def init_cache(cfg: ModelConfig, batch: int, window: int):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = di // s.head_dim
    G, k = _n_groups(cfg), cfg.hybrid.attn_every
    return {
        "mamba": {
            "h": jnp.zeros((G, k, batch, nh, s.d_state, s.head_dim),
                           jnp.float32),
            "conv": jnp.zeros((G, k, batch, s.d_conv - 1,
                               di + 2 * s.d_state), cfg.cdtype),
        },
        # shared attention: one ring-buffer KV cache per group *call site*
        "attn": {
            "k": jnp.zeros((G, batch, window, cfg.n_kv_heads, cfg.hd()),
                           cfg.cdtype),
            "v": jnp.zeros((G, batch, window, cfg.n_kv_heads, cfg.hd()),
                           cfg.cdtype),
        },
    }


def decode_step(cfg: ModelConfig, params, cache, token, position, *,
                w_live: int | None = None):
    x = params["embed"].astype(cfg.cdtype)[token]
    sp = params["shared_attn"]

    def group(x, scanned):
        gp, mcache, acache = scanned
        a, acache = dense.attn_block_decode(
            sp, L.rms_norm(x, sp["ln1"], cfg.norm_eps), acache, position,
            cfg, w_live=w_live)
        x = x + a
        x = x + dense.mlp_block(
            sp, L.rms_norm(x, sp["ln2"], cfg.norm_eps), cfg)

        def inner(x, sc):
            lp, st = sc
            y, st = mamba.mamba2_decode(
                lp, L.rms_norm(x, lp["norm"], cfg.norm_eps), st, cfg)
            return x + y, st

        x, mcache = jax.lax.scan(inner, x, (gp, mcache),
                                 unroll=cfg.hybrid.attn_every
                                 if cfg.unroll_layers else 1)
        return x, (mcache, acache)

    x, (mcache, acache) = jax.lax.scan(
        group, x, (params["mamba"], cache["mamba"], cache["attn"]),
        unroll=_n_groups(cfg) if cfg.unroll_layers else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.cdtype)
    return x @ head, {"mamba": mcache, "attn": acache}
