"""Paper Figure 9: MA-Echo as the aggregation operator inside
multi-round FL — convergence in fewer rounds than FedAvg/FedProx."""
from __future__ import annotations


from benchmarks.common import BENCH_DATA, MLP, row
from repro.core.maecho import MAEchoConfig
from repro.data.partition import label_shard_partition
from repro.data.synthetic import generate
from repro.fl.client import LocalTrainConfig
from repro.fl.rounds import MultiRoundConfig, run_multi_round


def run(quick: bool = False):
    data = generate(BENCH_DATA)
    n_clients, sample = (6, 3) if quick else (20, 5)
    rounds = 3 if quick else 8
    parts = label_shard_partition(data["train_y"], n_clients, 2, seed=0)
    client_data = [(data["train_x"][ix], data["train_y"][ix])
                   for ix in parts]
    for method in ("fedavg", "fedprox", "maecho"):
        cfg = MultiRoundConfig(
            n_rounds=rounds, n_clients=n_clients, sample_clients=sample,
            method=method,
            local=LocalTrainConfig(epochs=2, max_steps=60,
                                   fedprox_mu=0.1 if method ==
                                   "fedprox" else 0.0),
            maecho=MAEchoConfig(tau=20, eta=0.5, mu=20.0))
        hist, final = run_multi_round(
            MLP, client_data, (data["test_x"], data["test_y"]), cfg)
        for rnd, acc in enumerate(hist):
            row(f"fig9/{method}/round{rnd}", 0, f"acc={acc:.4f}")


if __name__ == "__main__":
    run()
