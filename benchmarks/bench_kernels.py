"""Kernel micro-benchmarks: wall time of the jnp oracle path on CPU
(interpret-mode Pallas timing is not meaningful hardware signal; the
TPU numbers come from the roofline analysis) + allclose sanity.

Each row's ``derived`` records the effective Pallas interpret flag the
parity check ran under (``REPRO_PALLAS_INTERPRET``), so a trajectory
point says whether the kernel side was the interpreter or Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.kernels import ops, ref
from repro.kernels.env import interpret_default


def run(quick: bool = False):
    interp = interpret_default()
    k = jax.random.PRNGKey(0)
    # maecho_update
    N, out_d, in_d = 5, 512, 512
    W = jax.random.normal(k, (out_d, in_d))
    V = jax.random.normal(jax.random.fold_in(k, 1), (N, out_d, in_d))
    P = jax.random.normal(jax.random.fold_in(k, 2),
                          (N, in_d, in_d)) * 0.1
    alpha = jnp.ones(N) / N
    fn = jax.jit(lambda: ref.maecho_update_ref(W, V, P, alpha, 0.5))
    fn()
    _, us = timed(fn)
    got = ops.maecho_update(W, V, P, alpha, eta=0.5)
    ok = np.allclose(np.asarray(got),
                     np.asarray(ref.maecho_update_ref(W, V, P, alpha,
                                                      0.5)), atol=1e-3)
    row("kernels/maecho_update_512x512_N5", us, f"allclose={ok} interpret={interp}")

    # maecho_gram / maecho_v_update (streaming-pipeline stages)
    fn = jax.jit(lambda: ref.maecho_gram_ref(W, V, P))
    fn()
    _, us = timed(fn)
    ok = np.allclose(np.asarray(ops.maecho_gram(W, V, P)),
                     np.asarray(fn()), atol=1e-2, rtol=1e-4)
    row("kernels/maecho_gram_512x512_N5", us, f"allclose={ok} interpret={interp}")

    fn = jax.jit(lambda: ref.maecho_v_update_ref(W, V, P, 0.5))
    fn()
    _, us = timed(fn)
    ok = np.allclose(np.asarray(ops.maecho_v_update(W, V, P, frac=0.5)),
                     np.asarray(fn()), atol=1e-3)
    row("kernels/maecho_v_update_512x512_N5", us, f"allclose={ok} interpret={interp}")

    # block-RLS
    d, b = 512, 64
    Q = jnp.eye(d)
    Xb = jax.random.normal(k, (b, d))
    fn = jax.jit(lambda: ref.block_rls_update_ref(Q, Xb, 1.0))
    fn()
    _, us = timed(fn)
    got = ops.block_rls_update(Q, Xb, 1.0)
    ok = np.allclose(np.asarray(got),
                     np.asarray(ref.block_rls_update_ref(Q, Xb, 1.0)),
                     atol=1e-3)
    row("kernels/block_rls_512_b64", us, f"allclose={ok} interpret={interp}")

    # flash attention
    B, S, H, D = 2, 512, 4, 64
    q = jax.random.normal(k, (B, S, H, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, H, D))
    fn = jax.jit(lambda: ref.flash_attention_ref(q, kk, v, causal=True))
    fn()
    _, us = timed(fn)
    got = ops.flash_attention(q, kk, v, causal=True, bq=128, bk=128)
    ok = np.allclose(np.asarray(got),
                     np.asarray(ref.flash_attention_ref(q, kk, v,
                                                        causal=True)),
                     atol=1e-4)
    row("kernels/flash_attention_512x4x64", us, f"allclose={ok} interpret={interp}")


if __name__ == "__main__":
    run()
