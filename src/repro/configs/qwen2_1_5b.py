"""qwen2-1.5b — dense GQA with QKV bias [arXiv:2407.10671]."""
from repro.configs.common import smoke_reduce
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab=151936, head_dim=128, qkv_bias=True,
        rope_theta=1000000.0, tie_embeddings=True,
        microbatches=4,
        source="arXiv:2407.10671",
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config(), n_kv_heads=2, n_heads=4)
