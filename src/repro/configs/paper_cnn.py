"""The paper's CIFAR-10 CNN (3 conv + 3 fc), §7."""
from repro.fl.models import CNN_SPEC, PaperModelSpec


def config() -> PaperModelSpec:
    return CNN_SPEC


def smoke_config() -> PaperModelSpec:
    import dataclasses
    return dataclasses.replace(
        CNN_SPEC, in_shape=(8, 8, 3), conv_channels=(8, 8, 8),
        fc_hidden=(16, 16))
