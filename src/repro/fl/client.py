"""Client-side FL: local training to convergence + projection-matrix
estimation (the one extra forward epoch the paper budgets in §6).

The client API is model-family agnostic: it works for the paper's
MLP/CNN/CVAE (``repro.fl.models``) and, through the same projection
machinery, for the LLM zoo (see ``repro.fl.llm_adapter``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import projections as proj
from repro.fl import models as pm
from repro.models.layers import softmax_xent
from repro.optim import sgd
from repro.utils import trees


@dataclasses.dataclass(frozen=True)
class LocalTrainConfig:
    epochs: int = 10
    batch_size: int = 64
    lr: float = 0.01
    momentum: float = 0.5         # the paper's client recipe (§7.1)
    max_steps: int = 0            # 0 = epochs * steps_per_epoch
    fedprox_mu: float = 0.0       # FedProx proximal term (baseline)
    seed: int = 0


def _batches(x, y, bs, rng):
    n = len(x)
    order = rng.permutation(n)
    for s in range(0, n - bs + 1, bs):
        ix = order[s:s + bs]
        yield jnp.asarray(x[ix]), jnp.asarray(y[ix])


import functools


@functools.lru_cache(maxsize=64)
def _jitted_step(spec: pm.PaperModelSpec, cfg: LocalTrainConfig,
                 use_anchor: bool):
    """One jitted train step per (spec, cfg) — a fresh @jax.jit closure
    per client call exhausts XLA:CPU's JIT dylib budget after ~100
    clients (benchmarks run hundreds of local trainings per process)."""
    opt = sgd(cfg.lr, cfg.momentum)

    def loss_fn(p, bx, by, anchor):
        logits = pm.forward(spec, p, bx)
        l = softmax_xent(logits, by)
        if use_anchor and cfg.fedprox_mu > 0:
            sq = trees.tree_dot(trees.tree_sub(p, anchor),
                                trees.tree_sub(p, anchor))
            l = l + 0.5 * cfg.fedprox_mu * sq
        return l

    @jax.jit
    def step(p, s, bx, by, t, anchor):
        l, g = jax.value_and_grad(loss_fn)(p, bx, by, anchor)
        p, s = opt.update(g, s, p, t)
        return p, s, l

    return opt, step


def train_classifier(spec: pm.PaperModelSpec, params, x, y,
                     cfg: LocalTrainConfig,
                     anchor=None) -> tuple:
    """SGD local training.  ``anchor`` enables the FedProx term.
    Returns (params, final_loss)."""
    opt, step_anchor = _jitted_step(spec, cfg, anchor is not None)
    opt_state = opt.init(params)
    anchor_arg = anchor if anchor is not None else params

    def step(p, s, bx, by, t):
        return step_anchor(p, s, bx, by, t, anchor_arg)

    rng = np.random.RandomState(cfg.seed)
    t, loss = 0, jnp.float32(0)
    for _ in range(cfg.epochs):
        for bx, by in _batches(x, y, cfg.batch_size, rng):
            params, opt_state, loss = step(params, opt_state, bx, by, t)
            t += 1
            if cfg.max_steps and t >= cfg.max_steps:
                return params, float(loss)
    return params, float(loss)


@functools.lru_cache(maxsize=32)
def _jitted_forward(spec: pm.PaperModelSpec):
    return jax.jit(lambda p, bx: pm.forward(spec, p, bx))


def evaluate_classifier(spec: pm.PaperModelSpec, params, x, y,
                        batch: int = 512) -> float:
    fwd = _jitted_forward(spec)
    correct = 0
    n = (len(x) // batch) * batch or len(x)
    for s in range(0, n, batch):
        logits = fwd(params, jnp.asarray(x[s:s + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, -1) ==
                               jnp.asarray(y[s:s + batch])))
    return correct / n


# --------------------------------------------------------------------------
# projection estimation (one forward epoch, streaming block-RLS)
# --------------------------------------------------------------------------
def compute_projections(spec: pm.PaperModelSpec, params, x,
                        alpha: float = 1.0, batch: int = 256,
                        max_samples: int = 2048):
    """Per-layer projectors onto the span of layer-input features.

    Returns a pytree structurally matching ``params`` where each "W"
    projector is the (d_in, d_in) row-space matrix P and each "b"
    projector is the scalar full rule (DESIGN.md §4).

    ``alpha`` (the paper's z) is the energy floor: with row-normalised
    features, only directions carrying >~alpha total squared energy are
    captured by P.  alpha=1.0 keeps P concentrated on the dominant
    feature subspace — the regime the paper's Table 6 SVD-compression
    results show their projectors live in (EXPERIMENTS.md §Calibration
    has the sweep; alpha=1e-3 saturates P to full rank and collapses
    MA-Echo toward vanilla averaging).
    """
    n = min(len(x), max_samples)
    xs = x[:n]
    if n == 0:
        # a client with no data contributes no feature constraints:
        # zero rows are RLS no-ops, so P comes out as the zero matrix
        xs = np.zeros((1,) + tuple(x.shape[1:]), np.float32)
        n = 1

    # collect per-layer null projectors Q, then P = I - Q
    Qs: Optional[list] = None
    for s in range(0, n, batch):
        bx = jnp.asarray(xs[s:s + batch])
        _, feats = pm.forward(spec, params, bx, return_features=True)
        if Qs is None:
            Qs = [proj.null_projector_init(f.shape[-1]) for f in feats]
        for i, f in enumerate(feats):
            f2 = f.reshape(-1, f.shape[-1])
            # normalise feature scale for conditioning
            f2 = f2 / jnp.maximum(jnp.linalg.norm(f2, axis=-1,
                                                  keepdims=True), 1e-6)
            Qs[i] = proj.null_projector_from_features_continue(
                Qs[i], f2, alpha)
    Ps = [proj.symmetrize(jnp.eye(Q.shape[0]) - Q) for Q in Qs]

    out = [{"W": Ps[i], "b": jnp.ones(())}
           for i in range(len(_layer_list(spec, params)))]
    return _relist(spec, params, out)


def _layer_list(spec, params):
    if spec.kind == "cvae":
        return params["dec"]
    return params


def _relist(spec, params, entries):
    if spec.kind == "cvae":
        return {"dec": entries}
    return entries
