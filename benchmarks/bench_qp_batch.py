"""Serial vs batched multi-leaf QP solve (ISSUE 2 tentpole).

The MA-Echo outer iteration solves one N×N projected-gradient QP per
leaf.  This suite times the two strategies head-to-head at growing
leaf counts L:

  - serial:  a Python loop of L jitted ``solve_qp`` calls — the old
    τ-loop shape, one dispatch + one fori_loop per leaf;
  - batched: one jitted ``solve_qp_batched`` call — a single vmapped
    PGD solve over the whole (L, N, N) stack.

A second pair of rows times full ``maecho_aggregate`` runs on a
multi-leaf model with ``qp_batched`` off/on, so the trajectory also
tracks the end-to-end effect on the aggregation hot path.  Rows land
in ``BENCH_qp_batch.json`` via ``benchmarks.run`` and are gated by
``tools/check_bench_regression.py``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core.maecho import MAEchoConfig, maecho_aggregate
from repro.core.qp import solve_qp, solve_qp_batched

_QP_ITERS = 300


@partial(jax.jit, static_argnames=("iters",))
def _batched(G, C, iters):
    return solve_qp_batched(G, C, iters)


def _gram_stack(L: int, N: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    A = rng.randn(L, N, 2 * N).astype(np.float32)
    return jnp.asarray(A @ A.transpose(0, 2, 1))


def _time_serial(G, C):
    def run():
        return [solve_qp(G[i], C, iters=_QP_ITERS)
                for i in range(G.shape[0])]
    run()                                   # compile
    outs, us = timed(run)
    for _ in range(2):                      # best-of-3: shed noise
        _, u = timed(run)
        us = min(us, u)
    return jnp.stack(outs), us


def _time_batched(G, C):
    fn = lambda: _batched(G, C, _QP_ITERS)  # noqa: E731
    fn()                                    # compile
    out, us = timed(fn)
    for _ in range(2):
        _, u = timed(fn)
        us = min(us, u)
    return out, us


def _multileaf_model(n_layers: int, n_clients: int, d: int = 48):
    """An n_layers-deep MLP pytree per client with dense projectors —
    n_layers QPs per outer iteration."""
    clients, projs = [], []
    for i in range(n_clients):
        k = jax.random.PRNGKey(7 * i + 1)
        w, p = {}, {}
        for l in range(n_layers):
            kl = jax.random.fold_in(k, l)
            w[f"l{l}"] = jax.random.normal(kl, (d, d)) * 0.3
            X = jax.random.normal(jax.random.fold_in(kl, 1), (8, d))
            Xn = X / jnp.maximum(jnp.linalg.norm(X, axis=1,
                                                 keepdims=True), 1e-6)
            p[f"l{l}"] = Xn.T @ Xn
        clients.append(w)
        projs.append(p)
    return clients, projs


def run(quick: bool = False):
    N, C = 8, 1.0
    for L in ([2, 4, 8] if quick else [2, 4, 8, 16, 32]):
        G = _gram_stack(L, N)
        a_serial, us_serial = _time_serial(G, C)
        a_batched, us_batched = _time_batched(G, C)
        match = np.allclose(np.asarray(a_serial), np.asarray(a_batched),
                            atol=1e-4)
        row(f"qp_batch/serial_L{L}_N{N}", us_serial, "")
        row(f"qp_batch/batched_L{L}_N{N}", us_batched,
            f"speedup={us_serial / max(us_batched, 1):.2f}x;"
            f"match={match}")

    # end-to-end: the τ-loop with per-leaf PGD vs one stacked solve
    n_layers = 4 if quick else 8
    clients, projs = _multileaf_model(n_layers, n_clients=N)
    cfg = MAEchoConfig(tau=10, eta=0.5, qp_iters=150)
    seq = dataclasses.replace(cfg, qp_batched=False)

    def agg(c):
        fn = lambda: maecho_aggregate(clients, projs, c)  # noqa: E731
        fn()
        out, us = timed(fn)
        for _ in range(2):
            _, u = timed(fn)
            us = min(us, u)
        return out, us

    w_seq, us_seq = agg(seq)
    w_bat, us_bat = agg(cfg)
    agree = np.allclose(np.asarray(w_seq["l0"]), np.asarray(w_bat["l0"]),
                        atol=1e-3)
    tag = f"{n_layers}leaves_N{N}"
    row(f"qp_batch/agg_seq_qp_{tag}", us_seq, "")
    row(f"qp_batch/agg_batched_qp_{tag}", us_bat,
        f"speedup={us_seq / max(us_bat, 1):.2f}x;match={agree}")


if __name__ == "__main__":
    run()
