"""Jit'd public wrappers + dispatch layer for the Pallas kernels.

Interpret mode: every wrapper takes ``interpret=None`` which resolves
through the ``REPRO_PALLAS_INTERPRET`` env var (default "1": kernel
bodies execute on CPU — this container has no TPU).  A TPU launch
flips the one switch (``REPRO_PALLAS_INTERPRET=0``) instead of editing
call sites.  The value is read at trace time, so set it before the
first jitted call of the process.

The ``maecho_*_auto`` wrappers are the backend used by
``core.maecho``'s fused streaming pipeline: they normalise the
projector kind (stacked scalar / diagonal / dense / factored
``{"U", "s"}``), zero-pad non-block-multiple shapes via ``_pad_to``
(zero padding is exact: padded residual tiles are identically zero),
and fall back to the jnp oracles in ``ref.py`` for shapes too small to
tile.  All of them assume the "oi" layout — ``core.maecho`` transposes
"io" leaves before dispatch.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro.kernels import env as _env
from repro.kernels import ref
from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import maecho_gram as _mg
from repro.kernels import maecho_update as _mu
from repro.kernels import maecho_v_update as _mv
from repro.kernels import rank_update as _ru

__all__ = [
    "flash_attention", "maecho_update", "maecho_update_factored",
    "maecho_update_diag", "maecho_gram", "maecho_gram_factored",
    "maecho_gram_diag", "maecho_v_update", "maecho_v_update_factored",
    "maecho_v_update_diag", "rank_downdate", "block_rls_update",
    "maecho_update_auto", "maecho_gram_auto", "maecho_v_update_auto",
    "maecho_streaming_step", "maecho_streaming_gram",
    "maecho_streaming_apply", "maecho_streaming_gram_stacked",
    "maecho_streaming_apply_stacked", "maecho_sharded_gram",
    "maecho_sharded_apply", "maecho_sharded_gram_stacked",
    "maecho_sharded_apply_stacked", "maecho_sharded2d_gram",
    "maecho_sharded2d_apply", "maecho_sharded2d_gram_stacked",
    "maecho_sharded2d_apply_stacked", "maecho_gram_cross",
    "maecho_streaming_gram_chunked", "maecho_streaming_apply_chunked",
    "maecho_streaming_gram_chunked_stacked",
    "maecho_streaming_apply_chunked_stacked",
    "maecho_sharded_gram_chunked", "maecho_sharded_apply_chunked",
    "sharded_ok", "axis_size_of",
    "fallback_warn", "flash_attention_auto", "interpret_default",
    "decode_attention", "decode_attention_auto", "decode_window_block",
    "live_window", "DEFAULT_BLOCK",
]

# one tile edge: the auto wrappers fall back to the jnp oracles below
# this, and core.maecho's backend="auto" keys off the same constant
DEFAULT_BLOCK = 128

# re-exported from env.py (the raw kernel modules resolve their
# interpret=None defaults there; ops keeps the public name)
interpret_default = _env.interpret_default


_warned_fallbacks: set[str] = set()


def fallback_warn(msg: str) -> None:
    """``warnings.warn`` once per distinct message.

    Silent degradation is the failure mode this guards: a leaf the
    caller believes is on the kernel / sharded fast path quietly
    running the jnp oracle.  Dispatch is trace-time, so the warning
    fires when the program is built, not per step; the dedup set keeps
    re-traces (new shapes, new cfg) from spamming."""
    if msg not in _warned_fallbacks:
        _warned_fallbacks.add(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=3)


def _resolve(interpret):
    return interpret_default() if interpret is None else bool(interpret)


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def _proj_kind(P) -> str:
    """Kind of a *stacked* (leading client axis) projector leaf."""
    if isinstance(P, dict):
        return "factored"
    if P.ndim == 1:
        return "scalar"          # (N,) stacked scalar full projectors
    if P.ndim == 2:
        return "diag"            # (N, in)
    return "full"                # (N, in, in)


def _as_diag(P, in_d: int):
    """Broadcast stacked scalars (N,) to a diagonal (N, in)."""
    return jnp.broadcast_to(P[:, None], (P.shape[0], in_d))


def _pad_wv(W, V, block):
    Wp, po = _pad_to(W, block, 0)
    Wp, pi = _pad_to(Wp, block, 1)
    if po or pi:
        Vp, _ = _pad_to(_pad_to(V, block, 1)[0], block, 2)
    else:
        Vp = V
    return Wp, Vp, po, pi


def _pad_factored(U, s, block):
    """Pad the in-axis to ``block``; pad the rank only when it exceeds
    one lane tile (bk = rank otherwise).  Zero-padded (U, s) columns
    produce zero compressed-residual columns — exact."""
    Up, _ = _pad_to(U, block, 1)
    kd = U.shape[2]
    if kd > block:
        Up, _ = _pad_to(Up, block, 2)
        sp, _ = _pad_to(s, block, 1)
    else:
        sp = s
    return Up, sp


def _pad_factored_stacked(U, s, block):
    """:func:`_pad_factored` for the stacked (N, L, in, k) layout —
    the same rule shifted by the flattened layer axis, shared by every
    stacked gram wrapper so the rank-padding exactness argument lives
    in one place."""
    Up, _ = _pad_to(U, block, 2)
    kd = U.shape[3]
    if kd > block:
        Up, _ = _pad_to(Up, block, 3)
        sp, _ = _pad_to(s, block, 2)
    else:
        sp = s
    return Up, sp


# --------------------------------------------------------------------------
# thin kernel wrappers (env-var interpret resolution)
# --------------------------------------------------------------------------
def maecho_update(W, V, P, alpha, *, eta: float = 1.0, bo: int = 128,
                  bi: int = 128, bk: int = 128, interpret=None):
    return _mu.maecho_update(W, V, P, alpha, eta=eta, bo=bo, bi=bi,
                             bk=bk, interpret=_resolve(interpret))


def maecho_update_factored(W, V, U, s, alpha, *, eta: float = 1.0,
                           bo: int = 128, bi: int = 128, bk: int = 128,
                           interpret=None):
    return _mu.maecho_update_factored(W, V, U, s, alpha, eta=eta, bo=bo,
                                      bi=bi, bk=bk,
                                      interpret=_resolve(interpret))


def maecho_update_diag(W, V, p, alpha, *, eta: float = 1.0,
                       bo: int = 128, bi: int = 128, interpret=None):
    return _mu.maecho_update_diag(W, V, p, alpha, eta=eta, bo=bo, bi=bi,
                                  interpret=_resolve(interpret))


def maecho_gram(W, V, P, *, bo: int = 128, bi: int = 128, bk: int = 128,
                interpret=None):
    return _mg.maecho_gram(W, V, P, bo=bo, bi=bi, bk=bk,
                           interpret=_resolve(interpret))


def maecho_gram_factored(W, V, U, s, *, bo: int = 128, bi: int = 128,
                         bk: int = 128, interpret=None):
    return _mg.maecho_gram_factored(W, V, U, s, bo=bo, bi=bi, bk=bk,
                                    interpret=_resolve(interpret))


def maecho_gram_diag(W, V, p, *, bo: int = 128, bi: int = 128,
                     interpret=None):
    return _mg.maecho_gram_diag(W, V, p, bo=bo, bi=bi,
                                interpret=_resolve(interpret))


def maecho_v_update(W, V, P, *, frac: float, norm: bool = False,
                    eps: float = 1e-12, bo: int = 128, bi: int = 128,
                    bk: int = 128, interpret=None):
    return _mv.maecho_v_update(W, V, P, frac=frac, norm=norm, eps=eps,
                               bo=bo, bi=bi, bk=bk,
                               interpret=_resolve(interpret))


def maecho_v_update_factored(W, V, U, s, *, frac: float,
                             norm: bool = False, eps: float = 1e-12,
                             bo: int = 128, bi: int = 128, bk: int = 128,
                             interpret=None):
    return _mv.maecho_v_update_factored(W, V, U, s, frac=frac, norm=norm,
                                        eps=eps, bo=bo, bi=bi, bk=bk,
                                        interpret=_resolve(interpret))


def maecho_v_update_diag(W, V, p, *, frac: float, norm: bool = False,
                         eps: float = 1e-12, bo: int = 128,
                         bi: int = 128, interpret=None):
    return _mv.maecho_v_update_diag(W, V, p, frac=frac, norm=norm,
                                    eps=eps, bo=bo, bi=bi,
                                    interpret=_resolve(interpret))


def maecho_gram_cross(Ra, Rb, *, bd: int = 512, interpret=None):
    return _mg.maecho_gram_cross(Ra, Rb, bd=bd,
                                 interpret=_resolve(interpret))


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 256,
                    bk: int = 256, interpret=None):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=_resolve(interpret))


def decode_attention(q, k_cache, v_cache, valid_mask, *, bw: int = 512,
                     interpret=None, fold_batch=None):
    return _da.decode_attention(q, k_cache, v_cache, valid_mask, bw=bw,
                                interpret=_resolve(interpret),
                                fold_batch=fold_batch)


def rank_downdate(Q, U, A, *, bo: int = 256, bj: int = 256,
                  interpret=None):
    return _ru.rank_downdate(Q, U, A, bo=bo, bj=bj,
                             interpret=_resolve(interpret))


def block_rls_update(Q, Xb, alpha: float = 1.0, *, bo: int = 256,
                     interpret=None):
    return _ru.block_rls_update(Q, Xb, alpha, bo=bo,
                                interpret=_resolve(interpret))


# --------------------------------------------------------------------------
# auto dispatch: kind normalisation + padding + small-shape fallback
# --------------------------------------------------------------------------
def _normalize_padded(W, V, P, block: int):
    """Shared front half of the auto wrappers: classify the projector
    and zero-pad every operand to block multiples.

    Returns ``(kind, Wp, Vp, Pk)`` where ``Pk`` is the padded kernel
    operand for the kind — an ``(U, s)`` tuple for "factored", a
    (N, in_p) diagonal for "scalar"/"diag" (scalars broadcast), or the
    (N, in_p, in_p) dense matrix for "full".
    """
    in_d = W.shape[1]
    kind = _proj_kind(P)
    Wp, Vp, po, pi = _pad_wv(W, V, block)
    if kind == "factored":
        Pk = _pad_factored(P["U"], P["s"], block)
    elif kind in ("scalar", "diag"):
        p = _as_diag(P, in_d) if kind == "scalar" else P
        Pk = _pad_to(p, block, 1)[0]
    else:
        Pk = (_pad_to(_pad_to(P, block, 1)[0], block, 2)[0]
              if (po or pi) else P)
    return kind, Wp, Vp, Pk


def maecho_update_auto(W, V, P, alpha, *, eta: float = 1.0,
                       block: int = 128, interpret=None):
    """Eq. 7 for any projector kind: kernel when tileable, oracle else."""
    out_d, in_d = W.shape
    if out_d < block or in_d < block:
        return ref.maecho_update_ref_any(W, V, P, alpha, eta)
    kind, Wp, Vp, Pk = _normalize_padded(W, V, P, block)
    if kind == "factored":
        out = maecho_update_factored(Wp, Vp, *Pk, alpha, eta=eta,
                                     interpret=interpret)
    elif kind == "full":
        out = maecho_update(Wp, Vp, Pk, alpha, eta=eta,
                            interpret=interpret)
    else:
        out = maecho_update_diag(Wp, Vp, Pk, alpha, eta=eta,
                                 interpret=interpret)
    return out[:out_d, :in_d]


def maecho_gram_auto(W, V, P, *, block: int = 128, interpret=None):
    """(N, N) projected-residual Gram for any projector kind."""
    out_d, in_d = W.shape
    if out_d < block or in_d < block:
        return ref.maecho_gram_ref(W, V, P)
    kind, Wp, Vp, Pk = _normalize_padded(W, V, P, block)
    if kind == "factored":
        return maecho_gram_factored(Wp, Vp, *Pk, interpret=interpret)
    if kind == "full":
        return maecho_gram(Wp, Vp, Pk, interpret=interpret)
    return maecho_gram_diag(Wp, Vp, Pk, interpret=interpret)


def maecho_v_update_auto(W, V, P, *, frac: float, norm: bool = False,
                         eps: float = 1e-12, block: int = 128,
                         interpret=None):
    """Eq. 11 for any projector kind.

    With ``norm=True`` the kernels need full rows resident (bi = padded
    in_d) — fine up to rows of ~16k fp32.
    """
    out_d, in_d = W.shape
    if out_d < block or in_d < block:
        return ref.maecho_v_update_ref(W, V, P, frac, norm, eps)
    kind, Wp, Vp, Pk = _normalize_padded(W, V, P, block)
    bi = Wp.shape[1] if norm else block
    if kind == "factored":
        out = maecho_v_update_factored(Wp, Vp, *Pk, frac=frac,
                                       norm=norm, eps=eps, bi=bi,
                                       interpret=interpret)
    elif kind == "full":
        out = maecho_v_update(Wp, Vp, Pk, frac=frac, norm=norm, eps=eps,
                              bi=bi, interpret=interpret)
    else:
        out = maecho_v_update_diag(Wp, Vp, Pk, frac=frac, norm=norm,
                                   eps=eps, bi=bi, interpret=interpret)
    return out[:, :out_d, :in_d]


def _eff_block(block: int, out_d: int, in_d: int,
               base: int = DEFAULT_BLOCK) -> int:
    """Clamp a requested streaming-pipeline tile edge to the leaf.

    A caller-tuned ``block`` above ``base`` (``MAEchoConfig.
    kernel_block``) must never push a leaf that tiles fine at ``base``
    onto the oracle, nor pad a dim far past its own next
    base-multiple — the effective edge is capped at the smaller dim's
    base-rounded size.  Eligibility ("too small to tile") is always
    judged at ``base``."""
    cap = max(base, min(-(-out_d // base) * base,
                        -(-in_d // base) * base))
    return min(block, cap)


def maecho_streaming_gram(W, V, P, *, block: int = DEFAULT_BLOCK,
                          interpret=None):
    """Gram half of the fused leaf iteration: returns ``(G, ctx)``.

    G is the (N, N) Eq. 6 Gram matrix; ``ctx`` is an opaque reuse
    context for :func:`maecho_streaming_apply` carrying the classified
    kind, the padded operands, and — on the factored path — the
    compressed residual A shared with the Eq. 7 kernel (the dominant
    O(N·out·in·k) einsum is not recomputed).  Splitting gram from
    apply is what lets ``core.maecho`` stack every leaf's Gram into
    one (L, N, N) batch and run a single vmapped QP solve per outer
    iteration instead of L sequential ones.
    """
    out_d, in_d = W.shape
    if out_d < DEFAULT_BLOCK or in_d < DEFAULT_BLOCK:
        fallback_warn(
            f"leaf (out={out_d}, in={in_d}) below one "
            f"{DEFAULT_BLOCK}-tile: running the jnp oracle instead of "
            f"the streaming kernels")
        return ref.maecho_gram_ref(W, V, P), ("ref", W, V, P,
                                              out_d, in_d)
    block = _eff_block(block, out_d, in_d)
    kind, Wp, Vp, Pk = _normalize_padded(W, V, P, block)
    if kind == "factored":
        from repro.kernels.maecho_gram import compressed_residual

        Up, sp = Pk
        A = compressed_residual(Wp, Vp, Up, sp)
        UT = jnp.swapaxes(Up, 1, 2).astype(jnp.float32)
        G = _mg.maecho_gram_left(A, UT, bo=block, bi=block, bk=block,
                                 interpret=_resolve(interpret))
        return G, (kind, Wp, Vp, (Up, sp, A, UT), out_d, in_d)
    if kind == "full":
        G = maecho_gram(Wp, Vp, Pk, bo=block, bi=block, bk=block,
                        interpret=interpret)
    else:
        G = maecho_gram_diag(Wp, Vp, Pk, bo=block, bi=block,
                             interpret=interpret)
    return G, (kind, Wp, Vp, Pk, out_d, in_d)


def maecho_streaming_apply(alpha, ctx, *, eta: float = 1.0,
                           frac: float = 0.5, norm: bool = False,
                           eps: float = 1e-12, block: int = DEFAULT_BLOCK,
                           interpret=None):
    """Update half of the fused leaf iteration: Eq. 7 then Eq. 11.

    ``ctx`` is the context returned by :func:`maecho_streaming_gram`
    for the same leaf (same padded operands — the pipeline stays in
    padded space; zero padding is invariant under all three passes).
    Returns ``(W', V')`` cropped back to the original shape.
    """
    kind, Wp, Vp, Pk, out_d, in_d = ctx
    if kind == "ref":
        W_new = ref.maecho_update_ref_any(Wp, Vp, Pk, alpha, eta)
        return W_new, ref.maecho_v_update_ref(W_new, Vp, Pk, frac,
                                              norm, eps)
    block = _eff_block(block, out_d, in_d)   # same clamp as the gram
    bi = Wp.shape[1] if norm else block
    if kind == "factored":
        Up, sp, A, UT = Pk
        Wn = _mu.maecho_update_left(Wp, A, UT, alpha, eta=eta,
                                    bo=block, bi=block, bk=block,
                                    interpret=_resolve(interpret))
        Vn = maecho_v_update_factored(Wn, Vp, Up, sp, frac=frac,
                                      norm=norm, eps=eps, bo=block,
                                      bi=bi, bk=block,
                                      interpret=interpret)
    elif kind == "full":
        Wn = maecho_update(Wp, Vp, Pk, alpha, eta=eta, bo=block,
                           bi=block, bk=block, interpret=interpret)
        Vn = maecho_v_update(Wn, Vp, Pk, frac=frac, norm=norm, eps=eps,
                             bo=block, bi=bi, bk=block,
                             interpret=interpret)
    else:
        Wn = maecho_update_diag(Wp, Vp, Pk, alpha, eta=eta, bo=block,
                                bi=block, interpret=interpret)
        Vn = maecho_v_update_diag(Wn, Vp, Pk, frac=frac, norm=norm,
                                  eps=eps, bo=block, bi=bi,
                                  interpret=interpret)
    return Wn[:out_d, :in_d], Vn[:, :out_d, :in_d]


def maecho_streaming_step(W, V, P, qp, *, eta: float = 1.0,
                          frac: float = 0.5, norm: bool = False,
                          eps: float = 1e-12, block: int = DEFAULT_BLOCK,
                          interpret=None):
    """One fused Algorithm-1 leaf iteration: gram → QP → Eq. 7 → Eq. 11.

    ``qp`` maps the (N, N) Gram matrix to the simplex weights α.  The
    projector is normalised and padded **once** (in the gram half) and
    the whole pipeline runs in padded space.  This is the single-leaf
    composition of :func:`maecho_streaming_gram` and
    :func:`maecho_streaming_apply`; the batched path in
    ``core.maecho`` calls the two halves directly around one stacked
    QP solve.  Layout is "oi"; shapes below one tile run the jnp
    oracles with the same QP.
    """
    G, ctx = maecho_streaming_gram(W, V, P, block=block,
                                   interpret=interpret)
    alpha = qp(G)
    return maecho_streaming_apply(alpha, ctx, eta=eta, frac=frac,
                                  norm=norm, eps=eps, block=block,
                                  interpret=interpret)


# --------------------------------------------------------------------------
# stacked-leaf streaming pipeline: the scan-layer axis rides the grid
# --------------------------------------------------------------------------
def _proj_kind_stacked(P) -> str:
    """Kind of a stacked projector leaf with (N, L) leading axes —
    every unstacked kind shifted by the flattened layer axis."""
    if isinstance(P, dict):
        return "factored"
    if P.ndim == 2:
        return "scalar"          # (N, L) stacked scalar full projectors
    if P.ndim == 3:
        return "diag"            # (N, L, in)
    return "full"                # (N, L, in, in)


def _normalize_padded_stacked(W, V, P, block: int):
    """Stacked analogue of :func:`_normalize_padded`: classify the
    projector of a flattened (L, out, in) leaf and zero-pad the
    out/in (and factored-rank) axes to block multiples.  The layer
    axis L is a grid axis, never padded."""
    in_d = W.shape[2]
    kind = _proj_kind_stacked(P)
    Wp, po = _pad_to(W, block, 1)
    Wp, pi = _pad_to(Wp, block, 2)
    Vp = (_pad_to(_pad_to(V, block, 2)[0], block, 3)[0]
          if (po or pi) else V)
    if kind == "factored":
        Pk = _pad_factored_stacked(P["U"], P["s"], block)
    elif kind in ("scalar", "diag"):
        p = (jnp.broadcast_to(P[:, :, None], P.shape + (in_d,))
             if kind == "scalar" else P)
        Pk = _pad_to(p, block, 2)[0]
    else:
        Pk = (_pad_to(_pad_to(P, block, 2)[0], block, 3)[0]
              if (po or pi) else P)
    return kind, Wp, Vp, Pk


def maecho_streaming_gram_stacked(W, V, P, *, block: int = DEFAULT_BLOCK,
                                  interpret=None):
    """Stacked gram half of the fused leaf iteration: ``(G, ctx)``.

    W: (L, out, in); V: (N, L, out, in); P stacked per kind.  G is the
    per-layer (L, N, N) Eq. 6 Gram stack from ONE kernel launch (the
    layer axis is the outermost grid dimension — see
    ``maecho_gram.maecho_gram_stacked``); ``ctx`` is the reuse payload
    for :func:`maecho_streaming_apply_stacked`, carrying the factored
    path's (N, L, out, k) compressed residual exactly like the
    per-layer pipeline.  Shapes below one tile fall back to the vmapped
    jnp oracle (same contract as :func:`maecho_streaming_gram`)."""
    L, out_d, in_d = W.shape
    if out_d < DEFAULT_BLOCK or in_d < DEFAULT_BLOCK:
        fallback_warn(
            f"stacked leaf (L={L}, out={out_d}, in={in_d}) below one "
            f"{DEFAULT_BLOCK}-tile: running the vmapped jnp oracle "
            f"instead of the stacked kernel grid")
        G = jax.vmap(ref.maecho_gram_ref, in_axes=(0, 1, 1))(W, V, P)
        return G, ("ref", W, V, P, out_d, in_d)
    block = _eff_block(block, out_d, in_d)
    kind, Wp, Vp, Pk = _normalize_padded_stacked(W, V, P, block)
    if kind == "factored":
        Up, sp = Pk
        A = _mg.compressed_residual(Wp, Vp, Up, sp)     # (N, L, out, k)
        UT = jnp.swapaxes(Up, 2, 3).astype(jnp.float32)
        G = _mg.maecho_gram_left_stacked(A, UT, bo=block, bi=block,
                                         bk=block,
                                         interpret=_resolve(interpret))
        return G, (kind, Wp, Vp, (Up, sp, A, UT), out_d, in_d)
    if kind == "full":
        G = _mg.maecho_gram_stacked(Wp, Vp, Pk, bo=block, bi=block,
                                    bk=block,
                                    interpret=_resolve(interpret))
    else:
        G = _mg.maecho_gram_diag_stacked(Wp, Vp, Pk, bo=block,
                                         bi=block,
                                         interpret=_resolve(interpret))
    return G, (kind, Wp, Vp, Pk, out_d, in_d)


def maecho_streaming_apply_stacked(alpha, ctx, *, eta: float = 1.0,
                                   frac: float = 0.5, norm: bool = False,
                                   eps: float = 1e-12,
                                   block: int = DEFAULT_BLOCK,
                                   interpret=None):
    """Stacked update half: per-layer Eq. 7 then Eq. 11 from one
    launch each.  ``alpha`` is the (L, N) per-layer solve stack;
    ``ctx`` comes from :func:`maecho_streaming_gram_stacked` for the
    same leaf.  Returns ``(W', V')`` cropped to the original shape."""
    kind, Wp, Vp, Pk, out_d, in_d = ctx
    itp = _resolve(interpret)
    if kind == "ref":
        W_new = jax.vmap(
            lambda w, v, p, a: ref.maecho_update_ref_any(w, v, p, a,
                                                         eta),
            in_axes=(0, 1, 1, 0))(Wp, Vp, Pk, alpha)
        V_new = jax.vmap(
            lambda w, v, p: ref.maecho_v_update_ref(w, v, p, frac,
                                                    norm, eps),
            in_axes=(0, 1, 1), out_axes=1)(W_new, Vp, Pk)
        return W_new, V_new
    block = _eff_block(block, out_d, in_d)   # same clamp as the gram
    bi = Wp.shape[2] if norm else block
    if kind == "factored":
        Up, sp, A, UT = Pk
        Wn = _mu.maecho_update_left_stacked(Wp, A, UT, alpha, eta=eta,
                                            bo=block, bi=block,
                                            bk=block, interpret=itp)
        Vn = _mv.maecho_v_update_factored_stacked(
            Wn, Vp, Up, sp, frac=frac, norm=norm, eps=eps, bo=block,
            bi=bi, bk=block, interpret=itp)
    elif kind == "full":
        Wn = _mu.maecho_update_stacked(Wp, Vp, Pk, alpha, eta=eta,
                                       bo=block, bi=block, bk=block,
                                       interpret=itp)
        Vn = _mv.maecho_v_update_stacked(Wn, Vp, Pk, frac=frac,
                                         norm=norm, eps=eps, bo=block,
                                         bi=bi, bk=block,
                                         interpret=itp)
    else:
        Wn = _mu.maecho_update_diag_stacked(Wp, Vp, Pk, alpha, eta=eta,
                                            bo=block, bi=block,
                                            interpret=itp)
        Vn = _mv.maecho_v_update_diag_stacked(Wn, Vp, Pk, frac=frac,
                                              norm=norm, eps=eps,
                                              bo=block, bi=bi,
                                              interpret=itp)
    return Wn[:, :out_d, :in_d], Vn[:, :, :out_d, :in_d]


# --------------------------------------------------------------------------
# mesh-sharded streaming pipeline: out-dim-parallel gram / apply
# --------------------------------------------------------------------------
def _axis_names(axis) -> tuple:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def axis_size_of(mesh, axis) -> int:
    """Product of the named mesh axes' sizes (absent axes count 1).

    Delegates to the sharding rules' ``mesh_axis_size`` — one copy of
    the axis-size contract (imported lazily: the kernels layer stays
    import-light)."""
    from repro.sharding.rules import mesh_axis_size

    return mesh_axis_size(mesh, _axis_names(axis))


def sharded_ok(out_d: int, in_d: int, axis_size: int,
               block: int = DEFAULT_BLOCK, warn: bool = False) -> bool:
    """Eligibility of a leaf for the out-dim-sharded pipeline.

    Both dims must reach one tile and the out-dim's *tile count* must
    divide evenly over the axis — the sharding rules' ``_ok``
    divisibility contract at block granularity (every device gets the
    same number of whole tiles; GSPMD-style uneven shards would skew
    the per-device kernels).  Ineligible leaves stay on the
    single-device kernel/oracle path; with ``warn=True`` (the dispatch
    path in ``core.maecho`` sets it) that fallback is surfaced once
    via :func:`fallback_warn` instead of happening silently.
    """
    if out_d < block or in_d < block:
        ok = False
    else:
        ok = (-(-out_d // block)) % axis_size == 0
    if not ok and warn:
        fallback_warn(
            f"sharded-ineligible leaf (out={out_d}, in={in_d}, "
            f"axis_size={axis_size}, block={block}): falling back to "
            f"the single-device dispatch")
    return ok


def maecho_sharded_gram(W, V, P, *, mesh, axis="data",
                        block: int = DEFAULT_BLOCK, interpret=None):
    """Out-dim-sharded gram half of the streaming pipeline.

    Same ``(G, ctx)`` contract as :func:`maecho_streaming_gram`, but
    the leaf's out-rows are split over the ``axis`` mesh axes with
    ``shard_map``: each device forms only its own
    (out / axis_size, in) residual tiles in VMEM, contracts a partial
    (N, N) Gram locally, and ONE ``psum`` over the axis reconstructs
    the full replicated Gram that feeds the (global, unchanged) QP
    solve.  The apply half (:func:`maecho_sharded_apply`) then runs
    purely locally on the owned rows — no further collectives.

    Operands are zero-padded so the out-dim is a multiple of
    ``block × axis_size`` (even, block-tileable shards; zero padding
    is exact for all three passes) and the in-dim to ``block``.  On
    the factored path the (N, out, k) compressed residual is computed
    *sharded* and carried in ``ctx`` for the Eq. 7 kernel — the
    compressed-residual reuse survives the sharding.  Callers gate
    eligibility with :func:`sharded_ok`; "oi" layout, like the rest of
    the kernel pipeline.
    """
    names = _axis_names(axis)
    asz = axis_size_of(mesh, axis)
    out_d, in_d = W.shape
    kind = _proj_kind(P)
    itp = _resolve(interpret)
    Wp, _ = _pad_to(_pad_to(W, block * asz, 0)[0], block, 1)
    Vp, _ = _pad_to(_pad_to(V, block * asz, 1)[0], block, 2)
    row = PartitionSpec(names, None)           # W rows
    crow = PartitionSpec(None, names, None)    # V / A rows (axis 1)
    rep2 = PartitionSpec(None, None)
    rep3 = PartitionSpec(None, None, None)
    if kind == "factored":
        Up, sp = _pad_factored(P["U"], P["s"], block)

        def body_f(Wl, Vl, U, s):
            A = _mg.compressed_residual(Wl, Vl, U, s)
            UT = jnp.swapaxes(U, 1, 2).astype(jnp.float32)
            Gl = _mg.maecho_gram_left(A, UT, interpret=itp)
            return jax.lax.psum(Gl, names), A

        G, A = shard_map(body_f, mesh=mesh,
                         in_specs=(row, crow, rep3, rep2),
                         out_specs=(rep2, crow),
                         check_rep=False)(Wp, Vp, Up, sp)
        return G, (kind, Wp, Vp, (Up, sp, A), out_d, in_d)
    if kind == "full":
        Pk = _pad_to(_pad_to(P, block, 1)[0], block, 2)[0]

        def body_d(Wl, Vl, Pl):
            return jax.lax.psum(
                _mg.maecho_gram(Wl, Vl, Pl, interpret=itp), names)

        G = shard_map(body_d, mesh=mesh, in_specs=(row, crow, rep3),
                      out_specs=rep2, check_rep=False)(Wp, Vp, Pk)
    else:                                   # scalar / diag
        p = _as_diag(P, in_d) if kind == "scalar" else P
        Pk = _pad_to(p, block, 1)[0]

        def body_g(Wl, Vl, pl):
            return jax.lax.psum(
                _mg.maecho_gram_diag(Wl, Vl, pl, interpret=itp), names)

        G = shard_map(body_g, mesh=mesh, in_specs=(row, crow, rep2),
                      out_specs=rep2, check_rep=False)(Wp, Vp, Pk)
    return G, (kind, Wp, Vp, Pk, out_d, in_d)


def maecho_sharded_apply(alpha, ctx, *, mesh, axis="data",
                         eta: float = 1.0, frac: float = 0.5,
                         norm: bool = False, eps: float = 1e-12,
                         block: int = DEFAULT_BLOCK, interpret=None):
    """Update half of the sharded pipeline: Eq. 7 then Eq. 11.

    ``ctx`` is the context from :func:`maecho_sharded_gram` for the
    same leaf.  Both phases are row-local under the same out-dim
    sharding: Eq. 7 scales the owned rows' residuals by the replicated
    α, and Eq. 11's row normalisation runs along the unsharded in-axis
    — zero collectives (the gram phase's single psum is the outer
    iteration's only one).  Returns ``(W', V')`` cropped to the
    original shape.
    """
    kind, Wp, Vp, Pk, out_d, in_d = ctx
    names = _axis_names(axis)
    itp = _resolve(interpret)
    bi = Wp.shape[1] if norm else block
    row = PartitionSpec(names, None)
    crow = PartitionSpec(None, names, None)
    rep1 = PartitionSpec(None)
    rep2 = PartitionSpec(None, None)
    rep3 = PartitionSpec(None, None, None)
    if kind == "factored":
        Up, sp, A = Pk

        def body_f(a, Wl, Vl, U, s, Al):
            UT = jnp.swapaxes(U, 1, 2).astype(jnp.float32)
            Wn = _mu.maecho_update_left(Wl, Al, UT, a, eta=eta,
                                        interpret=itp)
            Vn = _mv.maecho_v_update_factored(
                Wn, Vl, U, s, frac=frac, norm=norm, eps=eps, bi=bi,
                interpret=itp)
            return Wn, Vn

        Wn, Vn = shard_map(
            body_f, mesh=mesh,
            in_specs=(rep1, row, crow, rep3, rep2, crow),
            out_specs=(row, crow), check_rep=False)(
            alpha, Wp, Vp, Up, sp, A)
    elif kind == "full":
        def body_d(a, Wl, Vl, Pl):
            Wn = _mu.maecho_update(Wl, Vl, Pl, a, eta=eta,
                                   interpret=itp)
            Vn = _mv.maecho_v_update(Wn, Vl, Pl, frac=frac, norm=norm,
                                     eps=eps, bi=bi, interpret=itp)
            return Wn, Vn

        Wn, Vn = shard_map(
            body_d, mesh=mesh, in_specs=(rep1, row, crow, rep3),
            out_specs=(row, crow), check_rep=False)(alpha, Wp, Vp, Pk)
    else:                                   # scalar / diag
        def body_g(a, Wl, Vl, pl):
            Wn = _mu.maecho_update_diag(Wl, Vl, pl, a, eta=eta,
                                        interpret=itp)
            Vn = _mv.maecho_v_update_diag(Wn, Vl, pl, frac=frac,
                                          norm=norm, eps=eps, bi=bi,
                                          interpret=itp)
            return Wn, Vn

        Wn, Vn = shard_map(
            body_g, mesh=mesh, in_specs=(rep1, row, crow, rep2),
            out_specs=(row, crow), check_rep=False)(alpha, Wp, Vp, Pk)
    return Wn[:out_d, :in_d], Vn[:, :out_d, :in_d]


def maecho_sharded_gram_stacked(W, V, P, *, mesh, axis="data",
                                block: int = DEFAULT_BLOCK,
                                interpret=None):
    """Out-dim-sharded stacked gram half.

    Same contract as :func:`maecho_sharded_gram` with the flattened
    scan-layer axis riding the kernel grid inside every shard:
    W (L, out, in) splits its out-rows over ``axis``, each device runs
    ONE stacked kernel launch over its (L, out/axis_size, in) slab,
    and a single ``psum`` per leaf per outer iteration reconstructs
    the replicated (L, N, N) Gram stack that feeds the (unchanged)
    stacked QP solve.  The factored path's (N, L, out, k) compressed
    residual is computed sharded and carried in ``ctx``.
    """
    names = _axis_names(axis)
    asz = axis_size_of(mesh, axis)
    L, out_d, in_d = W.shape
    kind = _proj_kind_stacked(P)
    itp = _resolve(interpret)
    Wp = _pad_to(_pad_to(W, block * asz, 1)[0], block, 2)[0]
    Vp = _pad_to(_pad_to(V, block * asz, 2)[0], block, 3)[0]
    row = PartitionSpec(None, names, None)          # W rows (axis 1)
    crow = PartitionSpec(None, None, names, None)   # V / A rows (axis 2)
    rep3 = PartitionSpec(None, None, None)
    rep4 = PartitionSpec(None, None, None, None)
    if kind == "factored":
        Up, sp = _pad_factored_stacked(P["U"], P["s"], block)

        def body_f(Wl, Vl, U, s):
            A = _mg.compressed_residual(Wl, Vl, U, s)
            UT = jnp.swapaxes(U, 2, 3).astype(jnp.float32)
            Gl = _mg.maecho_gram_left_stacked(A, UT, interpret=itp)
            return jax.lax.psum(Gl, names), A

        G, A = shard_map(body_f, mesh=mesh,
                         in_specs=(row, crow, rep4, rep3),
                         out_specs=(rep3, crow),
                         check_rep=False)(Wp, Vp, Up, sp)
        return G, (kind, Wp, Vp, (Up, sp, A), out_d, in_d)
    if kind == "full":
        Pk = _pad_to(_pad_to(P, block, 2)[0], block, 3)[0]

        def body_d(Wl, Vl, Pl):
            return jax.lax.psum(
                _mg.maecho_gram_stacked(Wl, Vl, Pl, interpret=itp),
                names)

        G = shard_map(body_d, mesh=mesh, in_specs=(row, crow, rep4),
                      out_specs=rep3, check_rep=False)(Wp, Vp, Pk)
    else:                                   # scalar / diag
        p = (jnp.broadcast_to(P[:, :, None], P.shape + (in_d,))
             if kind == "scalar" else P)
        Pk = _pad_to(p, block, 2)[0]

        def body_g(Wl, Vl, pl_):
            return jax.lax.psum(
                _mg.maecho_gram_diag_stacked(Wl, Vl, pl_,
                                             interpret=itp), names)

        G = shard_map(body_g, mesh=mesh, in_specs=(row, crow, rep3),
                      out_specs=rep3, check_rep=False)(Wp, Vp, Pk)
    return G, (kind, Wp, Vp, Pk, out_d, in_d)


def maecho_sharded_apply_stacked(alpha, ctx, *, mesh, axis="data",
                                 eta: float = 1.0, frac: float = 0.5,
                                 norm: bool = False, eps: float = 1e-12,
                                 block: int = DEFAULT_BLOCK,
                                 interpret=None):
    """Stacked update half of the sharded pipeline: per-layer Eq. 7
    then Eq. 11, row-local on each device's owned out-rows under the
    same sharding as :func:`maecho_sharded_gram_stacked` — zero
    collectives (the gram psum is the iteration's only one).
    ``alpha`` is the replicated (L, N) per-layer solve stack.
    Returns ``(W', V')`` cropped to the original shape."""
    kind, Wp, Vp, Pk, out_d, in_d = ctx
    names = _axis_names(axis)
    itp = _resolve(interpret)
    bi = Wp.shape[2] if norm else block
    row = PartitionSpec(None, names, None)
    crow = PartitionSpec(None, None, names, None)
    rep2 = PartitionSpec(None, None)
    rep3 = PartitionSpec(None, None, None)
    rep4 = PartitionSpec(None, None, None, None)
    if kind == "factored":
        Up, sp, A = Pk

        def body_f(a, Wl, Vl, U, s, Al):
            UT = jnp.swapaxes(U, 2, 3).astype(jnp.float32)
            Wn = _mu.maecho_update_left_stacked(Wl, Al, UT, a, eta=eta,
                                                interpret=itp)
            Vn = _mv.maecho_v_update_factored_stacked(
                Wn, Vl, U, s, frac=frac, norm=norm, eps=eps, bi=bi,
                interpret=itp)
            return Wn, Vn

        Wn, Vn = shard_map(
            body_f, mesh=mesh,
            in_specs=(rep2, row, crow, rep4, rep3, crow),
            out_specs=(row, crow), check_rep=False)(
            alpha, Wp, Vp, Up, sp, A)
    elif kind == "full":
        def body_d(a, Wl, Vl, Pl):
            Wn = _mu.maecho_update_stacked(Wl, Vl, Pl, a, eta=eta,
                                           interpret=itp)
            Vn = _mv.maecho_v_update_stacked(Wn, Vl, Pl, frac=frac,
                                             norm=norm, eps=eps, bi=bi,
                                             interpret=itp)
            return Wn, Vn

        Wn, Vn = shard_map(
            body_d, mesh=mesh, in_specs=(rep2, row, crow, rep4),
            out_specs=(row, crow), check_rep=False)(alpha, Wp, Vp, Pk)
    else:                                   # scalar / diag
        def body_g(a, Wl, Vl, pl_):
            Wn = _mu.maecho_update_diag_stacked(Wl, Vl, pl_, a, eta=eta,
                                                interpret=itp)
            Vn = _mv.maecho_v_update_diag_stacked(
                Wn, Vl, pl_, frac=frac, norm=norm, eps=eps, bi=bi,
                interpret=itp)
            return Wn, Vn

        Wn, Vn = shard_map(
            body_g, mesh=mesh, in_specs=(rep2, row, crow, rep3),
            out_specs=(row, crow), check_rep=False)(alpha, Wp, Vp, Pk)
    return Wn[:, :out_d, :in_d], Vn[:, :, :out_d, :in_d]


# --------------------------------------------------------------------------
# 2-D (out × in) mesh-sharded pipeline: backend="sharded2d"
# --------------------------------------------------------------------------
def maecho_sharded2d_gram(W, V, P, *, mesh, axis_out="data",
                          axis_in="model", block: int = DEFAULT_BLOCK,
                          interpret=None):
    """2-D-sharded gram half: out-rows over ``axis_out`` AND
    in-columns over ``axis_in``.

    Each device forms only its own (out/osz, in/isz) tile of the
    projected residual — the dominant O(N·out·in²) projection FLOPs
    split over the *whole* osz × isz fleet, which is the point: a leaf
    whose out-dim tile count cannot divide the full device count 1-D
    can still span it as the product of two smaller per-axis factors
    (``rules.sharded_ok2d`` gates both dims).  The partial (N, N)
    Grams are reconstructed by ONE ``psum`` over BOTH axis groups —
    the leaf's only collective per outer iteration.

    The residual tile is formed as a left-factor product (``Δ`` rows
    against the projector's owned output columns), so dense and
    factored kinds ride the existing ``maecho_gram_left`` kernel and
    diagonal/scalar kinds the elementwise ``maecho_gram_diag`` on
    pre-sliced operands.  Operands are zero-padded to
    ``block × axis_size`` multiples on each sharded dim (zero padding
    is exact for all three passes).

    Returns ``(G, ctx)`` with ``ctx`` in the SAME format as
    :func:`maecho_sharded_gram` — the apply half reuses the 1-D
    row-local kernels verbatim (see :func:`maecho_sharded2d_apply`).
    """
    no, ni = _axis_names(axis_out), _axis_names(axis_in)
    allnames = no + ni
    osz = axis_size_of(mesh, axis_out)
    isz = axis_size_of(mesh, axis_in)
    out_d, in_d = W.shape
    kind = _proj_kind(P)
    itp = _resolve(interpret)
    Wp = _pad_to(_pad_to(W, block * osz, 0)[0], block * isz, 1)[0]
    Vp = _pad_to(_pad_to(V, block * osz, 1)[0], block * isz, 2)[0]
    row = PartitionSpec(no, None)
    crow = PartitionSpec(None, no, None)
    col3 = PartitionSpec(None, None, ni)
    rep2 = PartitionSpec(None, None)
    rep3 = PartitionSpec(None, None, None)
    if kind == "factored":
        Up, sp = _pad_factored(P["U"], P["s"], block)
        UTs = jnp.swapaxes(Up, 1, 2).astype(jnp.float32)

        def body_f(Wl, Vl, U, s, UTl):
            # A (full in-contraction, replicated over axis_in);
            # the gram contracts A against only the owned UT columns
            A = _mg.compressed_residual(Wl, Vl, U, s)
            Gl = _mg.maecho_gram_left(A, UTl, interpret=itp)
            return jax.lax.psum(Gl, allnames), A

        G, A = shard_map(body_f, mesh=mesh,
                         in_specs=(row, crow, rep3, rep2, col3),
                         out_specs=(rep2, crow),
                         check_rep=False)(Wp, Vp, Up, sp, UTs)
        return G, (kind, Wp, Vp, (Up, sp, A), out_d, in_d)
    if kind == "full":
        in_p = Wp.shape[1]
        Pk = _pad_to(_pad_to(P, in_p, 1)[0], in_p, 2)[0]

        def body_d(Wl, Vl, Pl):
            # residual tile = Δ @ P[:, owned columns]: the delta rows
            # are the left factor, the projector's owned output
            # columns the right — maecho_gram_left streams the tiles
            A = (Wl[None] - Vl).astype(jnp.float32)
            Gl = _mg.maecho_gram_left(A, Pl.astype(jnp.float32),
                                      interpret=itp)
            return jax.lax.psum(Gl, allnames)

        G = shard_map(body_d, mesh=mesh, in_specs=(row, crow, col3),
                      out_specs=rep2, check_rep=False)(Wp, Vp, Pk)
    else:                                   # scalar / diag
        p = _as_diag(P, in_d) if kind == "scalar" else P
        Pk = _pad_to(p, block * isz, 1)[0]

        def body_g(Wl, Vl, pl):
            # elementwise kind: 2-D-slicing the operands is exact
            return jax.lax.psum(
                _mg.maecho_gram_diag(Wl, Vl, pl, interpret=itp),
                allnames)

        G = shard_map(body_g, mesh=mesh,
                      in_specs=(PartitionSpec(no, ni),
                                PartitionSpec(None, no, ni),
                                PartitionSpec(None, ni)),
                      out_specs=rep2, check_rep=False)(Wp, Vp, Pk)
    return G, (kind, Wp, Vp, Pk, out_d, in_d)


def maecho_sharded2d_apply(alpha, ctx, *, mesh, axis_out="data",
                           axis_in="model", eta: float = 1.0,
                           frac: float = 0.5, norm: bool = False,
                           eps: float = 1e-12,
                           block: int = DEFAULT_BLOCK, interpret=None):
    """Update half of the 2-D pipeline: Eq. 7 then Eq. 11, row/col-local.

    Delegates to the 1-D row-local apply over ``axis_out``: the
    devices along ``axis_in`` hold replicated rows (the in-dim
    contraction of Eq. 11 needs full Δ' rows, which stay resident from
    the gram phase's in-replicated operands) and recompute identical
    row shards — ZERO collectives either way, so the gram phase's
    single two-axis psum remains the leaf's only one per outer
    iteration.  ``ctx`` comes from :func:`maecho_sharded2d_gram`
    (same layout as the 1-D context; the extra in-padding to
    ``block × axis_in_size`` is still a block multiple, which is all
    the kernels require)."""
    del axis_in  # rows-only: the in-group replicates the apply
    return maecho_sharded_apply(alpha, ctx, mesh=mesh, axis=axis_out,
                                eta=eta, frac=frac, norm=norm, eps=eps,
                                block=block, interpret=interpret)


def maecho_sharded2d_gram_stacked(W, V, P, *, mesh, axis_out="data",
                                  axis_in="model",
                                  block: int = DEFAULT_BLOCK,
                                  interpret=None):
    """Stacked 2-D gram half: same contract as
    :func:`maecho_sharded2d_gram` with the flattened scan-layer axis
    riding the kernel grid inside every (out × in) shard — ONE stacked
    launch per device and ONE two-axis ``psum`` per leaf per outer
    iteration carrying the whole (L, N, N) Gram stack."""
    no, ni = _axis_names(axis_out), _axis_names(axis_in)
    allnames = no + ni
    osz = axis_size_of(mesh, axis_out)
    isz = axis_size_of(mesh, axis_in)
    L, out_d, in_d = W.shape
    kind = _proj_kind_stacked(P)
    itp = _resolve(interpret)
    Wp = _pad_to(_pad_to(W, block * osz, 1)[0], block * isz, 2)[0]
    Vp = _pad_to(_pad_to(V, block * osz, 2)[0], block * isz, 3)[0]
    row = PartitionSpec(None, no, None)
    crow = PartitionSpec(None, None, no, None)
    col4 = PartitionSpec(None, None, None, ni)
    rep3 = PartitionSpec(None, None, None)
    rep4 = PartitionSpec(None, None, None, None)
    if kind == "factored":
        Up, sp = _pad_factored_stacked(P["U"], P["s"], block)
        UTs = jnp.swapaxes(Up, 2, 3).astype(jnp.float32)

        def body_f(Wl, Vl, U, s, UTl):
            A = _mg.compressed_residual(Wl, Vl, U, s)
            Gl = _mg.maecho_gram_left_stacked(A, UTl, interpret=itp)
            return jax.lax.psum(Gl, allnames), A

        G, A = shard_map(body_f, mesh=mesh,
                         in_specs=(row, crow, rep4, rep3, col4),
                         out_specs=(rep3, crow),
                         check_rep=False)(Wp, Vp, Up, sp, UTs)
        return G, (kind, Wp, Vp, (Up, sp, A), out_d, in_d)
    if kind == "full":
        in_p = Wp.shape[2]
        Pk = _pad_to(_pad_to(P, in_p, 2)[0], in_p, 3)[0]

        def body_d(Wl, Vl, Pl):
            # Δ (N, L, o_sh, in_p) is already the left-factor layout;
            # Pl (N, L, in_p, in_sh) carries the owned output columns
            A = (Wl[None] - Vl).astype(jnp.float32)
            Gl = _mg.maecho_gram_left_stacked(
                A, Pl.astype(jnp.float32), interpret=itp)
            return jax.lax.psum(Gl, allnames)

        G = shard_map(body_d, mesh=mesh, in_specs=(row, crow, col4),
                      out_specs=rep3, check_rep=False)(Wp, Vp, Pk)
    else:                                   # scalar / diag
        p = (jnp.broadcast_to(P[:, :, None], P.shape + (in_d,))
             if kind == "scalar" else P)
        Pk = _pad_to(p, block * isz, 2)[0]

        def body_g(Wl, Vl, pl_):
            return jax.lax.psum(
                _mg.maecho_gram_diag_stacked(Wl, Vl, pl_,
                                             interpret=itp), allnames)

        G = shard_map(body_g, mesh=mesh,
                      in_specs=(PartitionSpec(None, no, ni),
                                PartitionSpec(None, None, no, ni),
                                PartitionSpec(None, None, ni)),
                      out_specs=rep3, check_rep=False)(Wp, Vp, Pk)
    return G, (kind, Wp, Vp, Pk, out_d, in_d)


def maecho_sharded2d_apply_stacked(alpha, ctx, *, mesh,
                                   axis_out="data", axis_in="model",
                                   eta: float = 1.0, frac: float = 0.5,
                                   norm: bool = False,
                                   eps: float = 1e-12,
                                   block: int = DEFAULT_BLOCK,
                                   interpret=None):
    """Stacked 2-D apply: row/col-local per-layer Eq. 7 + Eq. 11 via
    the 1-D stacked apply over ``axis_out`` (the in-group replicates
    the rows — zero collectives, cf. :func:`maecho_sharded2d_apply`)."""
    del axis_in
    return maecho_sharded_apply_stacked(
        alpha, ctx, mesh=mesh, axis=axis_out, eta=eta, frac=frac,
        norm=norm, eps=eps, block=block, interpret=interpret)


# --------------------------------------------------------------------------
# client-chunked streaming pipeline: peak memory O(chunk), not O(N)
# --------------------------------------------------------------------------
def _slice_chunk(P, a: int, chunk: int):
    """Client-chunk ``a`` of a stacked projector operand (dicts slice
    leaf-wise: the factored kind stays factored through the chunking)."""
    if isinstance(P, dict):
        return {k: v[a * chunk:(a + 1) * chunk] for k, v in P.items()}
    return P[a * chunk:(a + 1) * chunk]


def _dyn_chunk(P, a, chunk: int):
    """Client-chunk ``a`` (a TRACED loop index) via ``dynamic_slice``
    — the loop-body form of :func:`_slice_chunk`.  Dynamic slicing is
    what actually bounds memory: a statically-unrolled sweep lets XLA
    CSE every chunk's residual into one live buffer each, rebuilding
    the O(N) footprint the chunking exists to remove."""
    def sl(x):
        return jax.lax.dynamic_slice_in_dim(x, a * chunk, chunk, axis=0)
    if isinstance(P, dict):
        return {k: sl(v) for k, v in P.items()}
    return sl(P)


def _pad_clients(W, V, P, chunk: int, kind: str):
    """Zero-pad the client axis to a ``chunk`` multiple.

    Padded anchors are W itself — their residual (W − W)P is
    identically zero whatever the projector — and padded projectors
    are zeros (belt and braces; the Gram/apply crops never read them).
    Exact for every pass, mirroring the ``_pad_to`` tile-padding
    argument on the feature axes."""
    N = V.shape[0]
    pad = (-N) % chunk
    if pad == 0:
        return V, P
    Vp = jnp.concatenate(
        [V, jnp.broadcast_to(W[None], (pad,) + W.shape).astype(V.dtype)],
        axis=0)
    if kind == "factored":
        Pp = {k: _pad_to(v, chunk, 0)[0] for k, v in P.items()}
    else:
        Pp = _pad_to(P, chunk, 0)[0]
    return Vp, Pp


def _chunked_resid(W, Va, Pa, kind: str):
    """Rᵢ = (W − Vᵢ)Pᵢ for ONE client chunk, any projector kind, with
    optional stacked-layer axes riding the einsum ellipsis.  This is
    the only place the chunked pipeline materializes residual rows —
    (chunk, […,] out, in) fp32, never the full client axis."""
    delta = (W[None] - Va).astype(jnp.float32)
    if kind == "full":
        return jnp.einsum("n...oi,n...ij->n...oj", delta,
                          Pa.astype(jnp.float32))
    if kind == "diag":
        return delta * Pa[..., None, :].astype(jnp.float32)
    if kind == "scalar":
        return delta * Pa[..., None, None].astype(jnp.float32)
    U = Pa["U"].astype(jnp.float32)
    A = (jnp.einsum("n...oi,n...ik->n...ok", delta, U)
         * Pa["s"][..., None, :].astype(jnp.float32))
    return jnp.einsum("n...ok,n...ik->n...oi", A, U)


def _pair_jnp(stacked: bool):
    """Chunk-pair contraction ⟨Rₐ, R_b⟩ on flat residual rows:
    (ca, D) × (cb, D) -> (ca, cb), or (ca, L, D) × (cb, L, D) ->
    (L, ca, cb) with the layer axis as a dot_general batch dim."""
    if stacked:
        return lambda Ra, Rb: jax.lax.dot_general(
            Ra, Rb, (((2,), (2,)), ((1,), (1,))),
            preferred_element_type=jnp.float32)
    return lambda Ra, Rb: jax.lax.dot_general(
        Ra, Rb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _chunked_gram_core(W, Vp, Pp, kind: str, chunk: int, stacked: bool,
                       pair):
    """Triangular chunk-pair sweep: the (ncpad, ncpad) Gram assembled
    from (chunk, chunk) blocks with at most TWO chunks' residuals
    resident at any point.  Row chunk a's residual is computed once
    and held across its inner sweep; the strict lower triangle is the
    mirror of the upper (⟨Rₐ, R_b⟩ is symmetric under transpose) — the
    recompute factor is (nc+1)/2 residual passes, all O(chunk) in
    memory.  ``pair`` is the block contraction (jnp dot or the Pallas
    ``maecho_gram_cross`` streamer).

    The sweep is a ``fori_loop`` over DYNAMIC chunk indices rather
    than a python unroll: unrolled, XLA common-subexpressions each
    chunk's residual across its (nc) pair uses and keeps every one
    live through the whole sweep — measured peak equal to the
    unchunked path.  The loop + ``dynamic_slice`` form is opaque to
    that hoist, so exactly Rₐ and R_b exist at any program point."""
    nc = Vp.shape[0] // chunk
    lead = 2 if stacked else 1

    def resid(a):
        """Flattened residual rows of (traced) chunk ``a``."""
        Va = jax.lax.dynamic_slice_in_dim(Vp, a * chunk, chunk, axis=0)
        R = _chunked_resid(W, Va, _dyn_chunk(Pp, a, chunk), kind)
        return R.reshape(R.shape[:lead] + (-1,))

    if nc == 1:                        # one chunk: no sweep, no loop
        R0 = _chunked_resid(W, Vp, Pp, kind)
        R0 = R0.reshape(R0.shape[:lead] + (-1,))
        return pair(R0, R0)

    npadc = nc * chunk
    gshape = ((W.shape[0], npadc, npadc) if stacked
              else (npadc, npadc))
    zeros = (0,) if stacked else ()

    def put(G, blk, a, b):
        # diagonal blocks (a == b) write twice; ⟨Rₐ, Rₐ⟩ equals its
        # own transpose bit-for-bit, so the second write is a no-op
        G = jax.lax.dynamic_update_slice(
            G, blk, zeros + (a * chunk, b * chunk))
        return jax.lax.dynamic_update_slice(
            G, jnp.swapaxes(blk, -1, -2),
            zeros + (b * chunk, a * chunk))

    def outer(a, G):
        Ra = resid(a)

        def inner(b, G):
            return put(G, pair(Ra, resid(b)), a, b)

        G = put(G, pair(Ra, Ra), a, a)
        return jax.lax.fori_loop(a + 1, nc, inner, G)

    return jax.lax.fori_loop(0, nc, outer,
                             jnp.zeros(gshape, jnp.float32))


def _chunked_apply_core(alpha, W, Vp, Pp, kind: str, chunk: int, N: int,
                        stacked: bool, *, eta: float, frac: float,
                        norm: bool, eps: float):
    """Chunk-wise Eq. 7 + Eq. 11: the Eq. 7 delta accumulates over
    chunk residuals of the ORIGINAL W (α zero-padded on dead clients),
    then a second chunk sweep rebuilds each chunk's anchors from W' —
    the full (N, out, in) residual never exists; the (N, …) V' output
    is assembled from per-chunk pieces."""
    nc = Vp.shape[0] // chunk
    npad = nc * chunk - N
    ap = alpha.astype(jnp.float32)
    if npad:
        widths = ((0, 0), (0, npad)) if stacked else ((0, npad),)
        ap = jnp.pad(ap, widths)

    def acc_body(a, acc):
        Va = jax.lax.dynamic_slice_in_dim(Vp, a * chunk, chunk, axis=0)
        Ra = _chunked_resid(W, Va, _dyn_chunk(Pp, a, chunk), kind)
        aa = jax.lax.dynamic_slice_in_dim(ap, a * chunk, chunk,
                                          axis=ap.ndim - 1)
        if stacked:
            return acc + jnp.einsum("la,al...->l...", aa, Ra)
        return acc + jnp.einsum("a,a...->...", aa, Ra)

    # same dynamic-index loops as the gram sweep (see
    # _chunked_gram_core): unrolled chunks get CSE'd into full-N
    # residency
    acc = jax.lax.fori_loop(0, nc, acc_body,
                            jnp.zeros(W.shape, jnp.float32))
    W_new = (W.astype(jnp.float32) - 2.0 * eta * acc).astype(W.dtype)

    def v_chunk(Va, Pa):
        delta = (W_new[None] - Va).astype(jnp.float32)
        Un = delta - frac * _chunked_resid(W_new, Va, Pa, kind)
        if norm:
            nrm = jnp.linalg.norm(Un, axis=-1, keepdims=True)
            Un = Un / jnp.maximum(nrm, eps)
        return (Va.astype(jnp.float32) + Un).astype(Vp.dtype)

    if nc == 1:
        return W_new, v_chunk(Vp, Pp)[:N]

    def v_body(a, Vout):
        Va = jax.lax.dynamic_slice_in_dim(Vp, a * chunk, chunk, axis=0)
        vn = v_chunk(Va, _dyn_chunk(Pp, a, chunk))
        return jax.lax.dynamic_update_slice_in_dim(Vout, vn, a * chunk,
                                                   axis=0)

    Vout = jax.lax.fori_loop(0, nc, v_body, jnp.zeros_like(Vp))
    return W_new, Vout[:N]


def _cross_pair(bd: int, itp: bool):
    """Pair contraction through the Pallas ``maecho_gram_cross``
    streamer (kernel-route leaves): flat rows are zero-padded to a
    ``bd`` multiple — zero feature columns add zero to every dot."""
    def pair(Ra, Rb):
        return _mg.maecho_gram_cross(_pad_to(Ra, bd, 1)[0],
                                     _pad_to(Rb, bd, 1)[0],
                                     bd=bd, interpret=itp)
    return pair


def maecho_streaming_gram_chunked(W, V, P, *, chunk: int,
                                  use_kernel: bool = False,
                                  bd: int = 512, interpret=None):
    """Client-chunked gram half: same ``(G, ctx)`` contract as
    :func:`maecho_streaming_gram`, but the (N, N) Gram accumulates
    over client chunks — peak residual residency is O(chunk·out·in),
    not O(N·out·in), which is what lets one aggregation span
    cross-device cohorts (N in the thousands).  With ``use_kernel``
    the (chunk, chunk) pair blocks stream through the Pallas
    ``maecho_gram_cross`` kernel (the ``rank_update`` tiled-accumulator
    idiom); otherwise a jnp dot — bit-identical math either way.
    Layout "oi"; exactness of the client padding lives in
    :func:`_pad_clients`."""
    N = V.shape[0]
    kind = _proj_kind(P)
    Vp, Pp = _pad_clients(W, V, P, chunk, kind)
    pair = (_cross_pair(bd, _resolve(interpret)) if use_kernel
            else _pair_jnp(False))
    G = _chunked_gram_core(W, Vp, Pp, kind, chunk, False, pair)
    return G[:N, :N], ("chunk", kind, W, Vp, Pp, N, chunk)


def maecho_streaming_apply_chunked(alpha, ctx, *, eta: float = 1.0,
                                   frac: float = 0.5,
                                   norm: bool = False,
                                   eps: float = 1e-12):
    """Chunked update half on the context from
    :func:`maecho_streaming_gram_chunked`.  Returns ``(W', V')`` with
    the client axis cropped back to N."""
    _, kind, W, Vp, Pp, N, chunk = ctx
    return _chunked_apply_core(alpha, W, Vp, Pp, kind, chunk, N, False,
                               eta=eta, frac=frac, norm=norm, eps=eps)


def maecho_streaming_gram_chunked_stacked(W, V, P, *, chunk: int,
                                          interpret=None):
    """Stacked client-chunked gram half: W (L, out, in),
    V (N, L, out, in), P stacked per kind.  Returns the (L, N, N)
    Gram stack accumulated over client chunks (pair blocks batch the
    layer axis through one dot_general) plus the apply context."""
    del interpret                      # jnp contraction path
    N = V.shape[0]
    kind = _proj_kind_stacked(P)
    Vp, Pp = _pad_clients(W, V, P, chunk, kind)
    G = _chunked_gram_core(W, Vp, Pp, kind, chunk, True,
                           _pair_jnp(True))
    return G[:, :N, :N], ("stkc", kind, W, Vp, Pp, N, chunk)


def maecho_streaming_apply_chunked_stacked(alpha, ctx, *,
                                           eta: float = 1.0,
                                           frac: float = 0.5,
                                           norm: bool = False,
                                           eps: float = 1e-12):
    """Stacked chunked update half; ``alpha`` is the (L, N) per-layer
    solve stack."""
    _, kind, W, Vp, Pp, N, chunk = ctx
    return _chunked_apply_core(alpha, W, Vp, Pp, kind, chunk, N, True,
                               eta=eta, frac=frac, norm=norm, eps=eps)


def maecho_sharded_gram_chunked(W, V, P, *, mesh, axis="data",
                                chunk: int, stacked: bool = False,
                                block: int = DEFAULT_BLOCK,
                                interpret=None):
    """Out-dim-sharded client-chunked gram half.

    The two memory axes compose: each device owns an out-row shard
    (padded to ``block × axis_size`` rows like the unchunked sharded
    pipeline) AND sweeps the client axis in chunks, so per-device
    residual residency is O(chunk · out/axis_size · in).  One ``psum``
    over ``axis`` reconstructs the replicated Gram — the chunk loop
    adds no collectives.  ``stacked`` selects the (L, out, in) layout
    with the per-layer (L, N, N) Gram stack."""
    del interpret                      # jnp contraction inside the shard
    names = _axis_names(axis)
    asz = axis_size_of(mesh, axis)
    kind = _proj_kind_stacked(P) if stacked else _proj_kind(P)
    N = V.shape[0]
    oax = 1 if stacked else 0
    out_d, in_d = W.shape[-2:]
    Wp = _pad_to(W, block * asz, oax)[0]
    Vr = _pad_to(V, block * asz, oax + 1)[0]
    Vp, Pp = _pad_clients(Wp, Vr, P, chunk, kind)
    pair = _pair_jnp(stacked)
    if stacked:
        wspec = PartitionSpec(None, names, None)
        vspec = PartitionSpec(None, None, names, None)
        gspec = PartitionSpec(None, None, None)
    else:
        wspec = PartitionSpec(names, None)
        vspec = PartitionSpec(None, names, None)
        gspec = PartitionSpec(None, None)

    def rep(x):
        return PartitionSpec(*([None] * x.ndim))

    if kind == "factored":
        pargs = (Pp["U"], Pp["s"])
        pspecs = (rep(Pp["U"]), rep(Pp["s"]))

        def rebuild(U, s):
            return {"U": U, "s": s}
    else:
        pargs = (Pp,)
        pspecs = (rep(Pp),)

        def rebuild(p):
            return p

    def body(Wl, Vl, *ps):
        Gl = _chunked_gram_core(Wl, Vl, rebuild(*ps), kind, chunk,
                                stacked, pair)
        return jax.lax.psum(Gl, names)

    G = shard_map(body, mesh=mesh, in_specs=(wspec, vspec) + pspecs,
                  out_specs=gspec, check_rep=False)(Wp, Vp, *pargs)
    return (G[..., :N, :N],
            ("shc", kind, Wp, Vp, Pp, N, chunk, out_d, in_d))


def maecho_sharded_apply_chunked(alpha, ctx, *, mesh, axis="data",
                                 stacked: bool = False,
                                 eta: float = 1.0, frac: float = 0.5,
                                 norm: bool = False, eps: float = 1e-12):
    """Sharded chunked update half: Eq. 7 + Eq. 11 run row-local on
    each device's owned out-rows, chunk-swept over clients — zero
    collectives (the gram psum is the iteration's only one).  Returns
    ``(W', V')`` cropped to the original out/in dims."""
    _, kind, Wp, Vp, Pp, N, chunk, out_d, in_d = ctx
    names = _axis_names(axis)
    if stacked:
        wspec = PartitionSpec(None, names, None)
        vspec = PartitionSpec(None, None, names, None)
    else:
        wspec = PartitionSpec(names, None)
        vspec = PartitionSpec(None, names, None)

    def rep(x):
        return PartitionSpec(*([None] * x.ndim))

    if kind == "factored":
        pargs = (Pp["U"], Pp["s"])
        pspecs = (rep(Pp["U"]), rep(Pp["s"]))

        def rebuild(U, s):
            return {"U": U, "s": s}
    else:
        pargs = (Pp,)
        pspecs = (rep(Pp),)

        def rebuild(p):
            return p

    def body(a, Wl, Vl, *ps):
        return _chunked_apply_core(a, Wl, Vl, rebuild(*ps), kind, chunk,
                                   N, stacked, eta=eta, frac=frac,
                                   norm=norm, eps=eps)

    Wn, Vn = shard_map(body, mesh=mesh,
                       in_specs=(rep(alpha), wspec, vspec) + pspecs,
                       out_specs=(wspec, vspec),
                       check_rep=False)(alpha, Wp, Vp, *pargs)
    if stacked:
        return Wn[:, :out_d, :in_d], Vn[:, :, :out_d, :in_d]
    return Wn[:out_d, :in_d], Vn[:, :out_d, :in_d]


def flash_attention_auto(q, k, v, *, causal: bool = True, bq: int = 256,
                         bk: int = 256, interpret=None):
    """Pad-to-block front end for the flash kernel.

    Causal self-attention (Sq == Sk): both sequences zero-pad to a
    shared block multiple — padded keys sit strictly after every real
    query, so the causal mask removes them and cropping the padded
    query rows is exact.  Non-causal: the kernel runs only when Sk is
    already a block multiple (zero-padded keys would enter an unmasked
    softmax); query rows still pad/crop freely.  Remaining shapes
    (causal with Sq != Sk — prefill-with-cache offsets) run the jnp
    oracle.
    """
    Sq, Sk = q.shape[1], k.shape[1]
    if causal and Sq == Sk:
        b = min(bq, bk)
        qp, _ = _pad_to(q, b, 1)
        kp, _ = _pad_to(k, b, 1)
        vp, _ = _pad_to(v, b, 1)
        out = flash_attention(qp, kp, vp, causal=True,
                              bq=min(bq, qp.shape[1]),
                              bk=min(bk, kp.shape[1]),
                              interpret=interpret)
        return out[:, :Sq]
    if not causal and Sk % min(bk, Sk) == 0:
        qp, _ = _pad_to(q, min(bq, Sq), 1)
        out = flash_attention(qp, k, v, causal=False,
                              bq=min(bq, qp.shape[1]),
                              bk=min(bk, Sk), interpret=interpret)
        return out[:, :Sq]
    return ref.flash_attention_ref(q, k, v, causal=causal)


def decode_window_block(W: int) -> int | None:
    """Largest supported window block dividing W (None: ineligible).

    Bigger blocks amortise per-block launch overhead; the skip
    granularity stays coarse enough that a partially-filled window
    still drops most dead blocks.
    """
    for bw in (512, 256, DEFAULT_BLOCK):
        if W % bw == 0:
            return bw
    return None


def live_window(w_live: int, W: int) -> int:
    """Round a live-slot upper bound up to a block multiple, capped at W.

    The serving fast path's static crop: a ring buffer whose highest
    written slot (host-known — the serve loop tracks positions in
    Python) is below ``w_live`` only ever has valid slots in
    ``[0, w_live)``, so the attention read can slice the cache there.
    Rounding to ``DEFAULT_BLOCK`` keeps the crop kernel-eligible and
    bounds recompiles to the caller's bucketing policy.
    """
    return min(W, -(-int(w_live) // DEFAULT_BLOCK) * DEFAULT_BLOCK)


def decode_attention_auto(q, k_cache, v_cache, valid_mask, *,
                          interpret=None, w_live: int | None = None):
    """Single-token KV-cache attention: Pallas window kernel when the
    window divides a block, dense jnp oracle otherwise (warn-once —
    the serving loop rounds its window to a block multiple precisely
    so this path stays hot).

    ``w_live`` (static python int) is the serving loop's bucketed
    upper bound on written ring-buffer slots: the cache/mask are
    cropped to it before the kernel, so a mostly-empty window pays
    only its live blocks in bytes touched, not just blocks skipped.
    Wraparound (any position ≥ W) must pass ``w_live=None`` / ``>= W``
    — the serve loop's bucket hits W exactly then.
    """
    W = k_cache.shape[1]
    if w_live is not None:
        wl = live_window(w_live, W)
        if wl < W:
            k_cache = k_cache[:, :wl]
            v_cache = v_cache[:, :wl]
            valid_mask = valid_mask[:, :wl]
            W = wl
    bw = decode_window_block(W)
    if bw is None:
        fallback_warn(
            f"decode window W={W} is not a {DEFAULT_BLOCK}-multiple: "
            f"running the dense jnp decode oracle")
        return ref.decode_attention_ref(q, k_cache, v_cache, valid_mask)
    return decode_attention(q, k_cache, v_cache, valid_mask, bw=bw,
                            interpret=interpret)
