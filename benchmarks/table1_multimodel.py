"""Paper Table 1: multi-model one-shot aggregation.

clients ∈ {5, 10, 20} × β ∈ {0.01, 0.1, 0.5}: Local acc / Average /
OT / MA-Echo / Ensemble, plus elapsed aggregation time (the paper's
elapsed-time rows; DENSE is out of scope — no server-side training by
construction of our setting).
"""
from __future__ import annotations

from benchmarks.common import (BENCH_DATA, MLP, ensemble_acc, row,
                               timed, train_locals)
from repro.core.maecho import MAEchoConfig
from repro.data.synthetic import generate
from repro.fl.client import evaluate_classifier
from repro.fl.server import one_shot_aggregate


def run(quick: bool = False):
    data = generate(BENCH_DATA)
    spec = MLP
    client_counts = [5] if quick else [5, 10, 20]
    betas = [0.01] if quick else [0.01, 0.1, 0.5]
    import jax
    for n in client_counts:
        for beta in betas:
            jax.clear_caches()
            parts, clients, projs, local = train_locals(
                spec, data, n, beta, epochs=4 if quick else 6)
            accs = {"local": local}
            times = {}
            for method in ("fedavg", "ot", "maecho"):
                kw = {"cfg": MAEchoConfig(tau=30, eta=0.5, mu=20.0)} \
                    if method == "maecho" else {}
                g, us = timed(one_shot_aggregate, spec, clients, projs,
                              method, **kw)
                accs[method] = evaluate_classifier(
                    spec, g, data["test_x"], data["test_y"])
                times[method] = us
            accs["ensemble"] = ensemble_acc(spec, clients, data)
            for m in ("local", "fedavg", "ot", "maecho", "ensemble"):
                row(f"table1/{n}clients/beta{beta}/{m}",
                    times.get(m, 0.0), f"acc={accs[m]:.4f}")


if __name__ == "__main__":
    run()
