"""Benchmark harness entry: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1,...]``

Prints ``name,us_per_call,derived`` CSV rows and persists each suite's
rows as ``BENCH_<suite>.json`` (appending a run entry per invocation —
the perf trajectory future PRs compare against; ``REPRO_BENCH_DIR``
overrides the output directory).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_kernels, bench_largeN_agg,
                        bench_maecho_agg, bench_qp_batch,
                        bench_serve, bench_sharded2d_agg,
                        bench_sharded_agg, bench_stacked_agg, fig4_cvae,
                        fig8_mu, fig9_multiround, roofline_report,
                        table1_multimodel, table4_beta_sweep,
                        table5_local_steps, table6_svd)
from benchmarks.common import drain_rows, persist_rows

SUITES = {
    "table1": table1_multimodel.run,
    "table4": table4_beta_sweep.run,
    "table5": table5_local_steps.run,
    "table6": table6_svd.run,
    "fig4": fig4_cvae.run,
    "fig8": fig8_mu.run,
    "fig9": fig9_multiround.run,
    "kernels": bench_kernels.run,
    "largeN_agg": bench_largeN_agg.run,
    "maecho_agg": bench_maecho_agg.run,
    "qp_batch": bench_qp_batch.run,
    "serve": bench_serve.run,
    "sharded_agg": bench_sharded_agg.run,
    "sharded2d_agg": bench_sharded2d_agg.run,
    "stacked_agg": bench_stacked_agg.run,
    "roofline": roofline_report.run,
}

# Perf suites whose BENCH_<suite>.json trajectories are gated by
# tools/check_bench_regression.py: each MUST carry a committed entry in
# benchmarks/baselines.json (the gate's --check-registered pass fails
# otherwise — a new perf suite without a baseline would gate nothing).
# The paper table/figure suites track accuracy, not perf, and are not
# listed.
PERF_SUITES = [
    "kernels",
    "largeN_agg",
    "maecho_agg",
    "qp_batch",
    "serve",
    "sharded_agg",
    "sharded2d_agg",
    "stacked_agg",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()

    names = (args.only.split(",") if args.only else list(SUITES))
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; choose from "
                 f"{sorted(SUITES)}")
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        import jax
        jax.clear_caches()       # cap XLA:CPU JIT dylib accumulation
        t0 = time.time()
        print(f"# suite {name}", flush=True)
        drain_rows()
        try:
            SUITES[name](quick=args.quick)
        except Exception as e:  # noqa: BLE001
            import traceback
            failures += 1
            print(f"{name}/SUITE_FAILED,0,{type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc()
            # a crashed suite's partial rows are not a trajectory point
            drain_rows()
        else:
            persist_rows(name, drain_rows(), args.quick)
        print(f"# suite {name} done in {time.time()-t0:.0f}s",
              flush=True)
    sys.exit(1 if failures else 0)


def run_all(quick=True):
    for fn in SUITES.values():
        fn(quick=quick)


if __name__ == "__main__":
    main()
