"""Optimizer, checkpoint, data pipeline, tree utils."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.serialize import load, save
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import MNIST_LIKE, generate, lm_token_batches
from repro.optim import adamw, clip_by_global_norm, cosine_schedule, sgd
from repro.utils import trees


# ----------------------------- optim ------------------------------------
def _quad_problem():
    target = jnp.asarray(np.random.RandomState(0).randn(8))
    params = {"w": jnp.zeros(8)}

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))
    return params, loss, target


@pytest.mark.parametrize("opt", [sgd(0.1, momentum=0.5),
                                 adamw(0.05)])
def test_optimizers_converge(opt):
    params, loss, target = _quad_problem()
    state = opt.init(params)
    for t in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, t)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(target), atol=1e-2)


def test_sgd_matches_paper_recipe():
    """lr=0.01, momentum=0.5 — one handworked step."""
    opt = sgd(0.01, momentum=0.5)
    p = {"w": jnp.ones(2)}
    s = opt.init(p)
    g = {"w": jnp.asarray([1.0, 2.0])}
    p1, s1 = opt.update(g, s, p, 0)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               [1 - 0.01, 1 - 0.02], atol=1e-7)
    p2, _ = opt.update(g, s1, p1, 1)
    # momentum: m = 0.5*g + g = 1.5g
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               [1 - 0.01 - 0.015, 1 - 0.02 - 0.03],
                               atol=1e-7)


def test_cosine_schedule_shape():
    sched = cosine_schedule(1.0, warmup=10, total=100)
    assert float(sched(0)) == 0.0
    assert abs(float(sched(10)) - 1.0) < 1e-5
    assert float(sched(100)) < float(sched(50)) < float(sched(10))


def test_grad_clip():
    g = {"a": jnp.ones(4) * 10}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(trees.tree_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == 20.0


# --------------------------- checkpoint ----------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones(2, jnp.bfloat16), {"c": 3, "d": "x"}],
            "e": (jnp.zeros(1), None)}
    path = os.path.join(tmp_path, "ckpt.msgpack")
    save(path, tree)
    out = load(path)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    assert out["b"][1] == {"c": 3, "d": "x"}
    assert out["b"][0].dtype == jnp.bfloat16
    assert isinstance(out["e"], tuple) and out["e"][1] is None


# ------------------------------ data -------------------------------------
def test_synthetic_learnable_and_low_rank():
    data = generate(MNIST_LIKE)
    X = data["train_x"][:1000]
    s = np.linalg.svd(X - X.mean(0), compute_uv=False)
    energy = np.cumsum(s ** 2) / np.sum(s ** 2)
    eff_rank = int(np.searchsorted(energy, 0.95))
    assert eff_rank < 80    # MNIST-like low effective rank (paper §6)
    # classes are separable by a linear probe on the latent structure
    assert len(np.unique(data["train_y"])) == 10


@given(st.floats(0.01, 100.0), st.integers(2, 10), st.integers(0, 20))
@settings(max_examples=15, deadline=None)
def test_dirichlet_partition_properties(beta, n_clients, seed):
    labels = np.random.RandomState(seed).randint(0, 10, size=2000)
    parts = dirichlet_partition(labels, n_clients, beta, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(np.unique(allidx))   # disjoint
    assert len(allidx) == len(labels)              # complete
    assert min(len(p) for p in parts) >= 2


def test_dirichlet_beta_controls_noniid():
    labels = np.random.RandomState(0).randint(0, 10, size=5000)

    def skew(beta):
        parts = dirichlet_partition(labels, 5, beta, seed=1)
        mats = np.stack([np.bincount(labels[p], minlength=10)
                         for p in parts]).astype(float)
        mats /= mats.sum(1, keepdims=True) + 1e-9
        return float(np.abs(mats - 0.1).mean())

    assert skew(0.01) > skew(100.0) * 2


def test_lm_batches_deterministic_structure():
    gen = lm_token_batches(100, 4, 32, 2, seed=0)
    b1 = next(gen)
    assert b1["tokens"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))


# --------------------------- tree utils ----------------------------------
def test_tree_paths_roundtrip():
    tree = {"a": {"b": jnp.ones(2)}, "c": jnp.zeros(3)}
    pairs = trees.tree_paths(tree)
    assert sorted(p for p, _ in pairs) == ["a.b", "c"]
    rebuilt = trees.tree_from_paths(pairs)
    np.testing.assert_array_equal(np.asarray(rebuilt["a"]["b"]),
                                  np.ones(2))


def test_stack_unstack_layers():
    layers = [{"w": jnp.ones(3) * i} for i in range(4)]
    stacked = trees.stack_layers(layers)
    assert stacked["w"].shape == (4, 3)
    out = trees.unstack_layers(stacked, 4)
    assert float(out[2]["w"][0]) == 2.0
