"""Uniform model API over all six architecture families.

``get_model(cfg)`` returns a ``ModelAPI`` namespace with:

  init_params(rng)                  -> params pytree
  loss_fn(params, batch)            -> scalar
  forward(params, batch)            -> logits
  init_cache(batch, window)         -> decode cache pytree
  decode_step(params, cache, token, position) -> (logits, cache)
  input_specs(shape)                -> {batch / decode inputs} as
                                       ShapeDtypeStructs (dry-run stand-ins)
  train_step / serve_step factories with the optimizer folded in.

This is the single surface the launcher, the dry-run driver, the FL
substrate, and the benchmarks all talk to.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import dense, encdec, hybrid, mamba, moe
from repro.models.config import InputShape, ModelConfig
from repro.optim import Optimizer


_FAMILY = {
    "dense": dense, "vlm": dense, "moe": moe,
    "ssm": mamba, "hybrid": hybrid, "encdec": encdec,
}


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig
    mod: Any

    # ----- parameters ----------------------------------------------------
    def init_params(self, rng):
        return self.mod.init_params(self.cfg, rng)

    def param_specs(self):
        return jax.eval_shape(
            lambda: self.mod.init_params(self.cfg, jax.random.PRNGKey(0)))

    # ----- forward / loss -------------------------------------------------
    def forward(self, params, batch):
        return self.mod.forward(self.cfg, params, batch)

    def loss_fn(self, params, batch):
        return self.mod.loss_fn(self.cfg, params, batch)

    def prefill(self, params, batch):
        """(last_logits, decode_cache) over the full prompt."""
        return self.mod.prefill(self.cfg, params, batch)

    # ----- decode ----------------------------------------------------------
    def init_cache(self, batch: int, window: int):
        return self.mod.init_cache(self.cfg, batch, window)

    def decode_step(self, params, cache, token, position, *,
                    w_live: int | None = None):
        """``w_live`` (static int) is the serving loop's bucketed bound
        on written ring-buffer slots — the cropped decode fast path.
        SSM caches have no KV window, so the family ignores it."""
        if w_live is None or self.cfg.family == "ssm":
            return self.mod.decode_step(self.cfg, params, cache, token,
                                        position)
        return self.mod.decode_step(self.cfg, params, cache, token,
                                    position, w_live=w_live)

    def cache_specs(self, batch: int, window: int):
        return jax.eval_shape(lambda: self.init_cache(batch, window))

    # ----- input stand-ins --------------------------------------------------
    def input_specs(self, shape: InputShape) -> dict:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct

        if shape.kind in ("train", "prefill"):
            if cfg.family == "encdec":
                e = cfg.encdec
                return {
                    "audio_embeds": sds((B, S, cfg.d_model), cfg.cdtype),
                    "tokens": sds((B, e.dec_seq), i32),
                    "labels": sds((B, e.dec_seq), i32),
                }
            if cfg.family == "vlm":
                P = cfg.vlm.n_patches
                n_text = S - P
                return {
                    "tokens": sds((B, n_text), i32),
                    "patch_embeds": sds((B, P, cfg.vlm.d_vision), cfg.cdtype),
                    "labels": sds((B, n_text), i32),
                }
            return {
                "tokens": sds((B, S), i32),
                "labels": sds((B, S), i32),
            }

        # decode: one token against a cache of length min(S, window)
        window = self.decode_window(shape)
        return {
            "token": sds((B, 1), i32),
            "position": sds((), i32),
            "cache": self.cache_specs(B, window),
        }

    def decode_window(self, shape: InputShape) -> int:
        """KV window for a decode shape: full S at 32k; sliding window at
        500k for attention archs (SSM caches ignore the value)."""
        cfg = self.cfg
        if shape.seq_len > 65536:
            return cfg.window
        return shape.seq_len

    # ----- step factories -----------------------------------------------
    def make_train_step(self, optimizer: Optimizer) -> Callable:
        """Train step with optional gradient accumulation
        (cfg.microbatches) — the memory knob that lets the 314B/405B
        configs fit (DESIGN.md §5)."""
        n_micro = self.cfg.microbatches

        def train_step(params, opt_state, batch, step):
            if n_micro <= 1:
                loss, grads = jax.value_and_grad(self.loss_fn)(params,
                                                               batch)
            else:
                def split(x):
                    return x.reshape((n_micro, x.shape[0] // n_micro)
                                     + x.shape[1:])

                micro = jax.tree_util.tree_map(split, batch)

                def acc_fn(carry, mb):
                    loss_acc, grad_acc = carry
                    l, g = jax.value_and_grad(self.loss_fn)(params, mb)
                    grad_acc = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(a.dtype), grad_acc, g)
                    return (loss_acc + l, grad_acc), None

                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    acc_fn, (jnp.float32(0.0), zeros), micro)
                loss = loss / n_micro
                grads = jax.tree_util.tree_map(lambda g: g / n_micro,
                                               grads)
            params, opt_state = optimizer.update(grads, opt_state, params,
                                                 step)
            return params, opt_state, loss
        return train_step

    def make_serve_step(self) -> Callable:
        """Greedy one-token serve step.  ``w_live`` is static (a python
        int per live-window bucket) — callers jitting the step mark it
        in ``static_argnames`` so each bucket compiles once."""
        def serve_step(params, cache, token, position, w_live=None):
            logits, cache = self.decode_step(params, cache, token,
                                             position, w_live=w_live)
            next_token = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            return next_token.astype(jnp.int32), cache
        return serve_step


def get_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.family not in _FAMILY:
        raise ValueError(f"unknown family {cfg.family!r}")
    return ModelAPI(cfg, _FAMILY[cfg.family])
