"""Fused streaming MA-Echo pipeline: property-based kernel-vs-oracle
parity (interpret mode) across projector kinds, shapes (tiled, padded,
sub-tile), conventions, stack_levels 0–3 and ragged client masks —
the strategies live in ``tests/strategies.py``; under the container's
deterministic hypothesis stub each ``@given`` runs a fixed seeded
sample, and the real ``hypothesis`` library upgrades the same tests to
adaptive property search.  Hand-picked regression cases (rank above
one tile, exact sub-tile fallback, the "io" transposition contract,
fori_loop + norm) stay alongside.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

import strategies as strat
from repro.core import projections as proj
from repro.core.maecho import MAEchoConfig, maecho_aggregate
from repro.kernels import ops, ref


def _one_device_mesh():
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:1]), ("data",))


def _one_device_mesh2d():
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


CFG = MAEchoConfig(tau=2, eta=0.5, qp_iters=60)


# --------------------------------------------------------------------------
# kernel-level property parity: the three auto wrappers
# --------------------------------------------------------------------------
@given(strat.seeds(), strat.n_clients(), strat.kinds(), strat.shapes())
@settings(max_examples=8, deadline=None)
def test_gram_parity(seed, n, kind, shape):
    W, V, P = strat.build_layer(seed, n, kind, shape)
    got = ops.maecho_gram_auto(W, V, P)
    want = ref.maecho_gram_ref(W, V, P)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-2, rtol=1e-4)


@given(strat.seeds(), strat.n_clients(), strat.kinds(), strat.shapes(),
       strat.bools())
@settings(max_examples=8, deadline=None)
def test_v_update_parity(seed, n, kind, shape, norm):
    W, V, P = strat.build_layer(seed, n, kind, shape)
    got = ops.maecho_v_update_auto(W, V, P, frac=0.5, norm=norm)
    want = ref.maecho_v_update_ref(W, V, P, 0.5, norm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@given(strat.seeds(), strat.n_clients(), strat.kinds(), strat.shapes())
@settings(max_examples=8, deadline=None)
def test_update_parity(seed, n, kind, shape):
    W, V, P = strat.build_layer(seed, n, kind, shape)
    alpha = jax.nn.softmax(jax.random.normal(
        jax.random.PRNGKey(seed + 1), (n,)))
    got = ops.maecho_update_auto(W, V, P, alpha, eta=0.7)
    want = ref.maecho_update_ref_any(W, V, P, alpha, 0.7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# stacked kernel-level property parity: the layer axis on the grid
# --------------------------------------------------------------------------
@given(strat.seeds(), strat.n_clients(), strat.kinds(),
       strat.shapes(), strat.bools())
@settings(max_examples=6, deadline=None)
def test_streaming_stacked_parity(seed, n, kind, shape, norm):
    L = 2 + seed % 3
    W, V, P = strat.build_layer(seed, n, kind, shape, lead=(L,))
    alpha = jax.nn.softmax(jax.random.normal(
        jax.random.PRNGKey(seed + 2), (L, n)), axis=-1)

    def step(W, V, P):
        G, ctx = ops.maecho_streaming_gram_stacked(W, V, P)
        Wn, Vn = ops.maecho_streaming_apply_stacked(
            alpha, ctx, eta=0.7, frac=0.5, norm=norm)
        return G, Wn, Vn

    G, Wn, Vn = jax.jit(step)(W, V, P)
    Gr = jax.vmap(ref.maecho_gram_ref, in_axes=(0, 1, 1))(W, V, P)
    Wr = jax.vmap(lambda w, v, p, a:
                  ref.maecho_update_ref_any(w, v, p, a, 0.7),
                  in_axes=(0, 1, 1, 0))(W, V, P, alpha)
    Vr = jax.vmap(lambda w, v, p:
                  ref.maecho_v_update_ref(w, v, p, 0.5, norm),
                  in_axes=(0, 1, 1), out_axes=1)(Wr, V, P)
    np.testing.assert_allclose(np.asarray(G), np.asarray(Gr),
                               atol=1e-2, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(Wn), np.asarray(Wr),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(Vn), np.asarray(Vr),
                               atol=1e-4)


# --------------------------------------------------------------------------
# full-aggregate property parity: oracle vs kernel/auto/sharded, with
# stacked leaves, mixed trees, both conventions and ragged masks
# --------------------------------------------------------------------------
def _agg(clients, projs, levels, convention, backend, mesh=None,
         mask=None, cfg=CFG):
    return maecho_aggregate(clients, projs, cfg, convention=convention,
                            stack_levels=levels, backend=backend,
                            mesh=mesh, client_mask=mask)


def _assert_close(a, b, tol=1e-3):
    for key in ("W", "b"):
        np.testing.assert_allclose(np.asarray(a[key]),
                                   np.asarray(b[key]), atol=tol)


@given(strat.seeds(), strat.n_clients(), strat.kinds(),
       strat.conventions(), strat.leads(), strat.shapes(),
       strat.masked())
@settings(max_examples=8, deadline=None)
def test_aggregate_parity_all_backends(seed, n, kind, convention, lead,
                                       shape, use_mask):
    """The acceptance property: kernel / auto / sharded all match the
    oracle to <1e-3 on a mixed pytree — any projector kind, either
    convention, stack_levels 0–3, tiled / padded / sub-tile shapes,
    with and without ragged client masks."""
    clients, projs, levels, mask = strat.build_case(
        seed, n, kind, convention, lead, shape, use_mask)
    want = _agg(clients, projs, levels, convention, "oracle", mask=mask)
    for backend, mesh in (("kernel", None), ("auto", None),
                          ("sharded", _one_device_mesh()),
                          ("sharded2d", _one_device_mesh2d())):
        got = _agg(clients, projs, levels, convention, backend,
                   mesh=mesh, mask=mask)
        _assert_close(want, got)


@pytest.mark.parametrize("convention", strat.CONVENTIONS)
@pytest.mark.parametrize("kind", strat.KINDS)
def test_aggregate_parity_each_kind_pinned(kind, convention):
    """Sampler-proof floor under the property test above: every
    projector kind × convention pair is guaranteed to exercise the
    kernel and sharded backends on a stacked leaf — in particular the
    dense-P "io" transposition contract (`_to_kernel_layout`'s
    trailing-axes swap) — whatever the (stub or real) sampler happens
    to draw."""
    clients, projs, levels, _ = strat.build_case(
        7, 3, kind, convention, (2,), (128, 128), False)
    want = _agg(clients, projs, levels, convention, "oracle")
    for backend, mesh in (("kernel", None),
                          ("sharded", _one_device_mesh()),
                          ("sharded2d", _one_device_mesh2d())):
        _assert_close(want, _agg(clients, projs, levels,
                                 backend=backend,
                                 convention=convention, mesh=mesh))


@given(strat.seeds(), strat.kinds(), strat.leads())
@settings(max_examples=4, deadline=None)
def test_aggregate_parity_sequential_qp(seed, kind, lead):
    """The ``qp_batched=False`` path dispatches per leaf (stacked
    leaves vmap the per-layer QP) — same parity bound."""
    cfg = dataclasses.replace(CFG, qp_batched=False)
    clients, projs, levels, _ = strat.build_case(
        seed, 3, kind, "oi", lead, (256, 140), False)
    want = _agg(clients, projs, levels, "oi", "oracle", cfg=cfg)
    got = _agg(clients, projs, levels, "oi", "kernel", cfg=cfg)
    _assert_close(want, got)


# --------------------------------------------------------------------------
# hand-picked regression cases
# --------------------------------------------------------------------------
def test_factored_rank_above_one_tile():
    """rank > 128 exercises the rank-axis padding/tiling path."""
    W, V, _ = strat.build_layer(31, 2, "diag", (128, 256))
    Ps = [strat.make_projector(jax.random.PRNGKey(50 + i), "factored",
                               (), 256, rank=150) for i in range(2)]
    P = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *Ps)
    got = ops.maecho_gram_auto(W, V, P)
    want = ref.maecho_gram_ref(W, V, P)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-2, rtol=1e-4)


def test_small_shapes_fall_back_to_oracle():
    """Below one tile the autos must return the oracle result exactly."""
    W, V, P = strat.build_layer(37, 2, "full", (6, 4))
    np.testing.assert_allclose(
        np.asarray(ops.maecho_gram_auto(W, V, P)),
        np.asarray(ref.maecho_gram_ref(W, V, P)), rtol=1e-6)


def test_backend_kernel_fori_loop_and_norm():
    """tau > 4 exercises the fori_loop outer path with kernels inside;
    norm=True exercises the fused row-norm."""
    clients, projs, levels, _ = strat.build_case(
        5, 3, "full", "oi", (), (140, 200), False)
    cfg = MAEchoConfig(tau=6, eta=0.5, qp_iters=60, norm=True, mu=2.0)
    a = maecho_aggregate(clients, projs, cfg, stack_levels=levels,
                         backend="oracle")
    b = maecho_aggregate(clients, projs, cfg, stack_levels=levels,
                         backend="kernel")
    np.testing.assert_allclose(np.asarray(a["W"]),
                               np.asarray(b["W"]), atol=1e-3)


def test_backend_stacked_fori_loop():
    """Stacked leaf under the fori_loop outer path (tau > 4): the
    stacked kernel grid lives inside the loop body."""
    clients, projs, levels, _ = strat.build_case(
        11, 3, "factored", "oi", (3,), (256, 140), False)
    cfg = MAEchoConfig(tau=6, eta=0.5, qp_iters=60)
    a = maecho_aggregate(clients, projs, cfg, stack_levels=levels,
                         backend="oracle")
    b = maecho_aggregate(clients, projs, cfg, stack_levels=levels,
                         backend="kernel")
    np.testing.assert_allclose(np.asarray(a["W"]),
                               np.asarray(b["W"]), atol=1e-3)


def test_backend_rejects_unknown():
    clients, projs, levels, _ = strat.build_case(
        11, 2, "scalar", "oi", (), (48, 64), False)
    with pytest.raises(ValueError):
        maecho_aggregate(clients, projs, MAEchoConfig(tau=1),
                         backend="gpu")


@pytest.mark.slow
def test_factor_projection_roundtrip_through_pipeline():
    """factor_projection output plugs straight into the kernel backend
    and agrees with the dense projector it factors (exact rank)."""
    d, r = 256, 256
    X = jax.random.normal(jax.random.PRNGKey(0), (40, d))
    P = proj.projection_from_features(X, 1e-3)
    clients, _, levels, _ = strat.build_case(
        13, 2, "scalar", "oi", (), (140, d), False)
    dense = [{"W": P, "b": jnp.ones(())} for _ in range(2)]
    fact = [{"W": proj.factor_projection(P, r), "b": jnp.ones(())}
            for _ in range(2)]
    cfg = MAEchoConfig(tau=2, eta=0.5, qp_iters=60)
    a = maecho_aggregate(clients, dense, cfg, backend="kernel")
    b = maecho_aggregate(clients, fact, cfg, backend="kernel")
    np.testing.assert_allclose(np.asarray(a["W"]),
                               np.asarray(b["W"]), atol=1e-3)
