"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("out_d,in_d,N", [
    (128, 128, 1), (128, 256, 2), (256, 128, 3), (384, 384, 5),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_maecho_update_sweep(out_d, in_d, N, dtype):
    k = jax.random.PRNGKey(out_d + in_d + N)
    W = jax.random.normal(k, (out_d, in_d), dtype)
    V = jax.random.normal(jax.random.fold_in(k, 1), (N, out_d, in_d),
                          dtype)
    P = (jax.random.normal(jax.random.fold_in(k, 2), (N, in_d, in_d))
         * 0.05).astype(dtype)
    alpha = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 3),
                                             (N,)))
    got = ops.maecho_update(W, V, P, alpha, eta=0.7)
    want = ref.maecho_update_ref(W.astype(jnp.float32),
                                 V.astype(jnp.float32),
                                 P.astype(jnp.float32), alpha, 0.7)
    tol = 1e-4 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=tol, rtol=tol)


def test_maecho_update_auto_pads_odd_shapes():
    k = jax.random.PRNGKey(0)
    W = jax.random.normal(k, (200, 300))
    V = jax.random.normal(jax.random.fold_in(k, 1), (2, 200, 300))
    P = jax.random.normal(jax.random.fold_in(k, 2), (2, 300, 300)) * 0.05
    alpha = jnp.array([0.6, 0.4])
    got = ops.maecho_update_auto(W, V, P, alpha, eta=1.0)
    want = ref.maecho_update_ref(W, V, P, alpha, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)


@pytest.mark.parametrize("d,b", [(128, 16), (256, 32), (512, 8)])
def test_block_rls_kernel(d, b):
    k = jax.random.PRNGKey(d + b)
    Q0 = jax.random.normal(k, (d, d))
    Q = Q0 @ Q0.T / d + jnp.eye(d)
    Xb = jax.random.normal(jax.random.fold_in(k, 1), (b, d))
    got = ops.block_rls_update(Q, Xb, 1.0, bo=128)
    want = ref.block_rls_update_ref(Q, Xb, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("S,Hq,Hkv,D", [
    (256, 4, 4, 64),    # MHA
    (256, 8, 2, 64),    # GQA 4:1
    (512, 4, 1, 128),   # MQA
    (256, 6, 6, 96),    # non-128 head_dim (whisper/phi3 shapes)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(S, Hq, Hkv, D, causal):
    k = jax.random.PRNGKey(S + Hq)
    B = 2
    q = jax.random.normal(k, (B, S, Hq, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, Hkv, D))
    got = ops.flash_attention(q, kk, v, causal=causal, bq=128, bk=128)
    want = ref.flash_attention_ref(q, kk, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    k = jax.random.PRNGKey(9)
    B, S, H, D = 1, 256, 2, 64
    q = jax.random.normal(k, (B, S, H, D), jnp.bfloat16)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, H, D),
                           jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, H, D),
                          jnp.bfloat16)
    got = ops.flash_attention(q, kk, v, causal=True, bq=128, bk=128)
    want = ref.flash_attention_ref(q, kk, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=0.05)


def test_kernel_used_inside_algorithm_one():
    """One Algorithm-1 iteration stepped with the fused kernel matches
    the pure-jnp layer step (integration of kernel with core)."""
    from repro.core.maecho import MAEchoConfig, _leaf_sequential
    from repro.core.plan import LeafPlan
    k = jax.random.PRNGKey(3)
    N, out_d, in_d = 2, 128, 128
    W = jax.random.normal(k, (out_d, in_d))
    V = jax.random.normal(jax.random.fold_in(k, 1), (N, out_d, in_d))
    P = jax.random.normal(jax.random.fold_in(k, 2), (N, in_d, in_d)) * 0.1
    cfg = MAEchoConfig(tau=1, eta=0.5, qp_iters=100)
    lp = LeafPlan("W", 0, "kernel", "full", out_d, in_d, 128)
    W1, _ = _leaf_sequential(W, V, P, lp, cfg, "oi")
    # recover alpha by construction: uniform when G symmetric-ish is
    # fine for this check — instead compare against ref with the same
    # alpha extracted via the kernel path on identical inputs
    from repro.core.qp import solve_qp
    R = jnp.einsum("noi,nij->noj", W[None] - V, P)
    G = jnp.einsum("noi,moi->nm", R, R)
    alpha = solve_qp(G, 1.0, iters=100)
    W_kernel = ops.maecho_update(W, V, P, alpha, eta=0.5)
    np.testing.assert_allclose(np.asarray(W1), np.asarray(W_kernel),
                               atol=1e-3)
