"""Analytic HBM-traffic model (flash-aware memory roofline term).

Why this exists: ``cost_analysis()['bytes accessed']`` on the CPU
backend counts every op's operands/results at (near-absent) fusion
boundaries, so it is a *fusion-free upper bound* — on a real TPU the
flash-attention chunk tiles and elementwise chains live in VMEM and
never hit HBM.  The dry-run records both numbers; bottleneck calls and
§Perf iterations use this model, which counts only tensors that
genuinely cross HBM on the TPU target:

  train:  3× param reads (fwd + bwd + remat recompute, per microbatch)
          + grad/momentum/param update traffic
          + per-layer activation checkpoints (write + read)
          + flash-attention q/k/v/o traffic with the kv re-read factor
          + logits + embedding gather
  prefill: forward-only subset + KV-cache writes
  decode:  active params once per token + KV (or SSM state) read/write

All numbers are per chip, honouring the sharding rules (model-axis
sharding divides feature dims; data/pod axes divide batch; fsdp weight
gathers are charged to the collective term, not HBM).
"""
from __future__ import annotations

from repro.models.config import InputShape, ModelConfig

BF16 = 2
F32 = 4


def _chips(mesh_name: str) -> tuple[int, int, int]:
    if mesh_name == "2x16x16":
        return 2, 16, 16
    return 1, 16, 16


def param_bytes_local(cfg: ModelConfig, n_model: int, n_data: int) -> float:
    """bf16 parameter bytes per chip under the sharding rules."""
    shard = n_model * (n_data if cfg.fsdp else 1)
    return cfg.n_params() * BF16 / shard


def active_param_bytes_local(cfg: ModelConfig, n_model: int,
                             n_data: int) -> float:
    shard = n_model * (n_data if cfg.fsdp else 1)
    return cfg.n_active_params() * BF16 / shard


def _attn_traffic(cfg: ModelConfig, tokens_local: int, seq: int) -> float:
    """flash q/k/v/o HBM traffic per layer (bf16), incl. kv re-reads."""
    if cfg.n_heads == 0:
        return 0.0
    hd = cfg.hd()
    q = tokens_local * cfg.n_heads * hd
    kv = tokens_local * cfg.n_kv_heads * hd * 2
    nq = max(1, seq // max(cfg.attn_chunk_q, 1))
    return (q * 2 + kv * (1 + nq)) * BF16


def _layer_act_traffic(cfg: ModelConfig, tokens_local: int,
                       seq: int, n_model: int) -> float:
    """forward HBM activation traffic per layer per chip (bf16)."""
    d = cfg.d_model
    t = tokens_local
    if cfg.family in ("dense", "vlm", "encdec"):
        f_eff = cfg.d_ff / n_model
        resid = 6 * t * d                     # norms + residual adds
        proj = t * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd() / n_model
        mlp = t * (2 * f_eff * 2 + 2 * d)     # gate/up h + down in/out
        return (resid + 2 * proj + mlp) * BF16 + _attn_traffic(
            cfg, t, seq) / n_model
    if cfg.family == "moe":
        m = cfg.moe
        f_eff = cfg.d_ff / n_model
        resid = 6 * t * d
        proj = t * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd() / n_model
        routed = t * m.top_k * (2 * f_eff * 2 + 2 * d) * m.capacity_factor
        shared = t * (2 * m.n_shared_experts * f_eff * 2 + 2 * d) \
            if m.n_shared_experts else 0.0
        dispatch = t * m.n_experts * 4        # routing tensors (f32-ish)
        return (resid + 2 * proj + routed + shared + dispatch) * BF16 + \
            _attn_traffic(cfg, t, seq) / n_model
    if cfg.family == "ssm":
        s = cfg.ssm
        di = s.d_inner(d) / n_model
        # in_proj out (2di), conv rw (2di), x_proj/dt (small), scan io
        # (dA,dBx,C read + y write ~ 3·di·ds f32 + di), out_proj io
        scan_io = t * (3 * di * s.d_state) * F32
        return (t * (6 * d + 6 * di) * BF16 + scan_io)
    if cfg.family == "hybrid":
        s = cfg.ssm
        di = s.d_inner(d) / n_model
        scan_io = t * (3 * di * s.d_state) * F32
        mamba = t * (6 * d + 6 * di) * BF16 + scan_io
        # shared attn+mlp charged once per group in layer count below
        return mamba
    raise ValueError(cfg.family)


def _shared_block_traffic(cfg: ModelConfig, tokens_local: int, seq: int,
                          n_model: int) -> float:
    d = cfg.d_model
    t = tokens_local
    f_eff = cfg.d_ff / n_model
    resid = 6 * t * d
    proj = t * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd() / n_model
    mlp = t * (2 * f_eff * 2 + 2 * d)
    return (resid + 2 * proj + mlp) * BF16 + _attn_traffic(
        cfg, t, seq) / n_model


def hbm_bytes(cfg: ModelConfig, shape: InputShape, kind: str,
              mesh_name: str) -> float:
    n_pod, n_data, n_model = _chips(mesh_name)
    batch_local = max(1, shape.global_batch // (n_pod * n_data))
    d = cfg.d_model

    if kind == "decode":
        # one token: every active param read once + cache traffic
        p = active_param_bytes_local(cfg, n_model, n_data)
        if cfg.family in ("ssm", "hybrid"):
            s = cfg.ssm
            di = s.d_inner(d) / n_model
            if cfg.family == "ssm":
                state = cfg.n_layers * batch_local * di * s.d_state * F32
            else:
                nh = s.d_inner(d) // s.head_dim / n_model
                state = cfg.n_layers * batch_local * nh * s.d_state * \
                    s.head_dim * F32
                W = min(shape.seq_len, cfg.window)
                groups = cfg.n_layers // cfg.hybrid.attn_every
                state += groups * batch_local * W * 2 * \
                    cfg.n_kv_heads * cfg.hd() * BF16 / \
                    (1 if cfg.n_kv_heads % n_model else n_model)
            return p + 2 * state
        W = min(shape.seq_len, cfg.window)
        kv_shard = n_model  # heads or head_dim sharded
        kv = cfg.n_layers * batch_local * W * 2 * cfg.n_kv_heads * \
            cfg.hd() * BF16 / kv_shard
        if cfg.family == "encdec":
            kv += cfg.n_layers * batch_local * cfg.encdec.enc_seq * 2 * \
                cfg.n_kv_heads * cfg.hd() * BF16 / kv_shard
        return p + kv

    # train / prefill
    if cfg.family == "encdec":
        seq = cfg.encdec.dec_seq
        tokens_local = batch_local * (cfg.encdec.dec_seq +
                                      shape.seq_len)  # dec + enc streams
    elif cfg.family == "vlm":
        seq = shape.seq_len
        tokens_local = batch_local * shape.seq_len
    else:
        seq = shape.seq_len
        tokens_local = batch_local * shape.seq_len
    if cfg.seq_shard:
        tokens_local //= n_model

    layer_fwd = _layer_act_traffic(cfg, tokens_local, seq, n_model)
    n_units = cfg.n_layers
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.hybrid.attn_every
        layer_total = layer_fwd * cfg.n_layers + groups * \
            _shared_block_traffic(cfg, tokens_local, seq, n_model)
    else:
        layer_total = layer_fwd * n_units

    p_local = param_bytes_local(cfg, n_model, n_data)
    embed_io = tokens_local * d * BF16 * 2
    logits = tokens_local * cfg.vocab / n_model * F32 * 2

    if kind == "prefill":
        kv_write = cfg.n_layers * tokens_local * 2 * cfg.n_kv_heads * \
            cfg.hd() * BF16 / n_model if cfg.n_heads else 0.0
        # last-token logits only
        return p_local + layer_total + embed_io + kv_write + \
            batch_local * cfg.vocab / n_model * F32

    # train: fwd + bwd(2×) + remat recompute(1×) on activations;
    # params re-read per microbatch for fwd+bwd; grads f32 rw per
    # microbatch; momentum + update once
    n_micro = max(1, cfg.microbatches)
    act = 4 * layer_total + 2 * embed_io + 2 * logits
    params_traffic = n_micro * 3 * p_local
    grad_traffic = n_micro * 2 * (p_local * 2)        # f32 rw per micro
    opt_traffic = 3 * p_local                          # m rw + p write
    return act + params_traffic + grad_traffic + opt_traffic
