"""Compile-once aggregation plans: the routing layer of MA-Echo.

Every ``maecho_aggregate`` call used to re-derive, per outer iteration
and per call site, which compute path each leaf takes — if/else chains
(`_use_kernel` / `_use_sharded` / `_stacked_route` / `_dispatch_leaf`)
smeared across ``core.maecho``, with ``dispatch_summary`` maintaining a
*second* copy of the same logic that could silently drift from what
actually executed.  This module replaces all of that with a
plan-then-execute split:

  - :func:`compile_plan` runs ONCE per (treedef, shapes, projector
    kinds, convention, stack_levels, backend, mesh, config) — the key
    is memoized, so repeated aggregations over the same model reuse
    the identical :class:`AggPlan` object — and produces one frozen
    :class:`LeafPlan` per leaf: the route, the kernel-layout dims, the
    effective tile edge, and the mesh axes that shard (and psum) it.
  - ``core.maecho``'s outer loop is a pure executor over those plans:
    it looks up ``leaf.route`` and calls the matching gram/apply pair.
    ``dispatch_summary`` is a *view* of the same compiled plan, so the
    coverage it reports is definitionally the coverage that runs.

Routes:

  ``oracle``     the jnp reference path (vmapped over a stacked leaf's
                 layer axis); consumes no mesh axes.
  ``kernel``     the fused streaming Pallas pipeline (2-D leaf).
  ``stacked``    the same pipeline with the flattened scan-layer axis
                 riding the kernel grid as its outermost dimension.
  ``sharded``    out-rows shard_map'd over ``cfg.mesh_axis``; one
                 (…, N, N) Gram psum over that axis per leaf per outer
                 iteration (stacked leaves fold their layer axis into
                 the per-device grid).
  ``sharded2d``  the 2-D (out × in) shard: out-rows over
                 ``cfg.mesh_axis`` AND in-columns over
                 ``cfg.mesh_in_axis`` ("model"), partial Grams psum'd
                 over BOTH axis groups in one collective; the apply
                 stays row/col-local.  Covers leaves whose out-dim
                 alone is too small to span the fleet.

All routing decisions are static-shape-only: arrays and
``jax.ShapeDtypeStruct`` trees are interchangeable inputs.  A forced
fast path (backend != "oracle"/"auto") that degrades to a weaker route
is surfaced once via ``ops.fallback_warn`` at plan-compile time —
silent degradation is the failure mode the plan layer exists to kill.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any

import jax

from repro.utils import trees

BACKENDS = ("oracle", "kernel", "auto", "sharded", "sharded2d")
ROUTES = ("oracle", "kernel", "stacked", "sharded", "sharded2d")

Pytree = Any


def _backend_error(backend) -> str:
    return (f"unknown backend {backend!r}; valid choices: "
            + ", ".join(BACKENDS))


def validate_backend(backend: str) -> None:
    """Reject unknown backend strings with the full choice list —
    shared by ``maecho_aggregate`` and the launch CLIs so a typo'd
    backend can never fall through to a default route."""
    if backend not in BACKENDS:
        raise ValueError(_backend_error(backend))


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    """Frozen per-leaf routing decision.

    ``out_d`` / ``in_d`` are the kernel-layout ("oi"-native) trailing
    dims — already convention-swapped; ``block`` is the effective
    streaming tile edge (``_eff_block``-clamped ``cfg.kernel_block``)
    on the kernel/stacked routes and the sharded pipelines' fixed
    ``DEFAULT_BLOCK`` otherwise; ``out_axes`` / ``in_axes`` are the
    mesh axis names sharding the leaf (empty on unsharded routes), and
    their concatenation is exactly the psum axis set of the leaf's one
    Gram collective.  ``client_chunk`` is the effective client-axis
    chunk (``cfg.client_chunk`` clamped to N; 0 = unchunked): when set,
    the leaf's Gram accumulates over blocks of clients so only
    ``client_chunk`` projections are resident per step."""
    path: str
    levels: int                 # leading stacked-layer axes (post-flatten)
    route: str                  # one of ROUTES
    kind: str                   # scalar | diag | full | factored
    out_d: int = 0
    in_d: int = 0
    block: int = 0
    out_axes: tuple = ()
    in_axes: tuple = ()
    client_chunk: int = 0

    @property
    def psum_axes(self) -> tuple:
        return self.out_axes + self.in_axes

    @property
    def stacked(self) -> bool:
        return self.levels > 0


@dataclasses.dataclass(frozen=True)
class AggPlan:
    """The compiled plan for one aggregation: per-leaf routes in
    ``tree_flatten`` order plus the dispatch inputs they were derived
    from.  Hashable — it is a static argument of the jitted executor,
    so one plan compiles to one XLA program."""
    backend: str
    convention: str
    leaves: tuple  # tuple[LeafPlan, ...]

    def per_leaf(self) -> list:
        """``dispatch_summary``'s per-leaf view: (path, levels, route)."""
        return [(lp.path, lp.levels, lp.route) for lp in self.leaves]

    def route_counts(self) -> dict:
        counts: dict = {}
        for lp in self.leaves:
            counts[lp.route] = counts.get(lp.route, 0) + 1
        return counts


# --------------------------------------------------------------------------
# static-shape predicates (ShapeDtypeStructs and arrays both work)
# --------------------------------------------------------------------------
def kernel_eligible(W, P, levels: int = 0) -> bool:
    """Leaf shapes the fused pipelines handle: a 2-D weight (plus
    ``levels`` leading stacked-layer axes) with a scalar / diagonal /
    dense / factored projector whose kind axes shift by the same
    ``levels``."""
    if getattr(W, "ndim", 0) != 2 + levels:
        return False
    if isinstance(P, dict):
        return (set(P) == {"U", "s"}
                and getattr(P["U"], "ndim", 0) == 3 + levels)
    return getattr(P, "ndim", -1) in (1 + levels, 2 + levels, 3 + levels)


def kernel_dims(W, convention: str) -> tuple:
    """(out_d, in_d) of a leaf in the "oi"-native kernel layout — the
    trailing two axes, swapped for "io" (stack axes don't matter)."""
    out_d, in_d = W.shape[-2:]
    return (out_d, in_d) if convention == "oi" else (in_d, out_d)


def proj_kind(P, levels: int = 0) -> str:
    """Kind of a *stacked* (leading client axis) projector leaf with
    ``levels`` leading layer axes."""
    if isinstance(P, dict):
        return "factored"
    nd = getattr(P, "ndim", -1) - levels
    if nd == 1:
        return "scalar"
    if nd == 2:
        return "diag"
    return "full"


def _axis_names(axis) -> tuple:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _mesh_has(mesh, axis) -> bool:
    return mesh is not None and all(
        n in mesh.shape for n in _axis_names(axis))


def leaf_route(W, P, levels: int, cfg, convention: str, backend: str,
               mesh=None, path: str = "") -> str:
    """Route of a single leaf under the given dispatch inputs — the one
    copy of the routing rules (:func:`compile_plan` maps it over the
    tree).  Static shapes only."""
    return _plan_leaf(path, W, P, levels, cfg, convention, backend,
                      mesh).route


def _eff_chunk(cfg, P, eligible: bool) -> int:
    """Effective client-axis chunk for one leaf: ``cfg.client_chunk``
    clamped to the client count N (chunk ≥ N would only manufacture
    dead padded clients), 0 on ineligible leaves — 1-D biases and
    other oracle-only shapes never chunk."""
    ck = int(getattr(cfg, "client_chunk", 0) or 0)
    if not eligible or ck <= 0:
        return 0
    n = (P["U"].shape[0] if isinstance(P, dict) else P.shape[0])
    return min(ck, int(n))


def _plan_leaf(path: str, W, P, levels: int, cfg, convention: str,
               backend: str, mesh) -> LeafPlan:
    from repro.kernels import ops

    eligible = kernel_eligible(W, P, levels)
    kind = proj_kind(P, levels) if eligible else "none"
    ck = _eff_chunk(cfg, P, eligible)
    if not eligible or backend == "oracle":
        if eligible is False and backend not in ("oracle", "auto") \
                and getattr(W, "ndim", 0) > 1:
            # a forced fast path silently running the oracle is the
            # drift mode the plan layer guards — warn once at compile
            ops.fallback_warn(
                f"leaf {path or '<leaf>'} (shape={tuple(W.shape)}, "
                f"levels={levels}) ineligible for backend="
                f"{backend!r}: falling back to the "
                f"{'vmapped ' if levels else ''}jnp oracle")
        return LeafPlan(path, levels, "oracle", kind, client_chunk=ck)
    out_d, in_d = kernel_dims(W, convention)
    sub_tile = min(out_d, in_d) < ops.DEFAULT_BLOCK

    if backend == "sharded2d" and ck:
        # the 2-D shard splits the in-columns, but the chunked residual
        # sweep contracts full rows per client block — the combination
        # has no kernel.  Degrade loudly to the 1-D out-dim shard,
        # which composes with chunking (rows × client blocks).
        ops.fallback_warn(
            f"leaf {path or '<leaf>'} requests backend='sharded2d' "
            f"with client_chunk={ck}: the 2-D shard does not compose "
            f"with client chunking — degrading to the 1-D out-dim "
            f"shard")
    elif backend == "sharded2d" and _mesh_has(mesh, cfg.mesh_axis):
        if _mesh_has(mesh, cfg.mesh_in_axis):
            from repro.sharding.rules import sharded_ok2d

            osz = ops.axis_size_of(mesh, cfg.mesh_axis)
            isz = ops.axis_size_of(mesh, cfg.mesh_in_axis)
            if sharded_ok2d(out_d, in_d, osz, isz, warn=True):
                return LeafPlan(path, levels, "sharded2d", kind, out_d,
                                in_d, ops.DEFAULT_BLOCK,
                                _axis_names(cfg.mesh_axis),
                                _axis_names(cfg.mesh_in_axis))
        else:
            # the in-axis is simply absent from the mesh — still a
            # forced-2-D request degrading, so warn like every other
            # rung of the fallback chain
            ops.fallback_warn(
                f"mesh lacks the in-axis {cfg.mesh_in_axis!r} for "
                f"backend='sharded2d': leaf {path or '<leaf>'} "
                f"(out={out_d}, in={in_d}) degrading to the 1-D "
                f"out-dim shard / single-device dispatch")
    if backend in ("sharded", "sharded2d") \
            and _mesh_has(mesh, cfg.mesh_axis):
        if ops.sharded_ok(out_d, in_d,
                          ops.axis_size_of(mesh, cfg.mesh_axis),
                          warn=True):
            return LeafPlan(path, levels, "sharded", kind, out_d, in_d,
                            ops.DEFAULT_BLOCK,
                            _axis_names(cfg.mesh_axis),
                            client_chunk=ck)
    # single-device streaming rule: "kernel" forces it for any
    # tileable leaf; "auto" (and the sharded backends' fallback)
    # promotes only leaves big enough to tile.  Sub-tile leaves run
    # the oracle — the plan records what actually executes (the old
    # dispatch forced them into the streaming wrappers, which then
    # ref-fell-back internally).
    if not sub_tile:
        block = _eff_tile(cfg, out_d, in_d)
        return LeafPlan(path, levels, "stacked" if levels else "kernel",
                        kind, out_d, in_d, block, client_chunk=ck)
    if backend not in ("oracle", "auto"):
        ops.fallback_warn(
            f"{'stacked ' if levels else ''}leaf {path or '<leaf>'} "
            f"(out={out_d}, in={in_d}"
            f"{f', levels={levels}' if levels else ''}) below one "
            f"{ops.DEFAULT_BLOCK}-tile for backend={backend!r}: "
            f"running the {'vmapped ' if levels else ''}jnp oracle "
            f"instead of the streaming kernels")
    return LeafPlan(path, levels, "oracle", kind, out_d, in_d,
                    client_chunk=ck)


def _eff_tile(cfg, out_d: int, in_d: int) -> int:
    from repro.kernels.ops import DEFAULT_BLOCK, _eff_block

    return _eff_block(cfg.kernel_block or DEFAULT_BLOCK, out_d, in_d)


# --------------------------------------------------------------------------
# compile + memoization
# --------------------------------------------------------------------------
class _ShapeOnly:
    """Hashable stand-in for a leaf in the memo key (shape is the only
    attribute routing reads)."""
    __slots__ = ("shape", "ndim")

    def __init__(self, shape):
        self.shape = tuple(int(d) for d in shape)
        self.ndim = len(self.shape)

    def __hash__(self):
        return hash(self.shape)

    def __eq__(self, other):
        return (isinstance(other, _ShapeOnly)
                and self.shape == other.shape)


def _leaf_key(p):
    if isinstance(p, dict):
        return {"U": _ShapeOnly(p["U"].shape),
                "s": _ShapeOnly(p["s"].shape)}
    return _ShapeOnly(p.shape)


class _FrozenProj:
    """Hashable wrapper for a projector descriptor (dicts don't hash)."""
    __slots__ = ("value",)

    def __init__(self, value):
        self.value = value

    def _key(self):
        v = self.value
        return (("factored", v["U"].shape, v["s"].shape)
                if isinstance(v, dict) else ("array", v.shape))

    def __hash__(self):
        return hash(self._key())

    def __eq__(self, other):
        return (isinstance(other, _FrozenProj)
                and self._key() == other._key())


@lru_cache(maxsize=256)
def _compile_cached(leaf_descs, cfg, convention, backend, mesh):
    leaves = tuple(
        _plan_leaf(path, w, p.value, lv, cfg, convention, backend, mesh)
        for path, w, p, lv in leaf_descs)
    return AggPlan(backend=backend, convention=convention, leaves=leaves)


def compile_plan(W0: Pytree, P: Pytree, levels_tree: Pytree, cfg,
                 convention: str = "oi", backend: str = "oracle",
                 mesh=None) -> AggPlan:
    """Compile (or fetch the memoized) :class:`AggPlan` for a model.

    ``W0`` / ``P`` are the global-weight and *stacked* (leading client
    axis) projector trees — arrays or ``jax.ShapeDtypeStruct``s both
    work, routing is static-shape-only.  ``levels_tree`` is the
    per-leaf stacked-layer-axis count (a matching pytree).  The memo
    key is (per-leaf path/shape/kind/levels, cfg, convention, backend,
    mesh): a second call over the same model returns the *same* plan
    object, so the executor's jit cache is hit instead of re-traced.
    """
    validate_backend(backend)
    treedef = jax.tree_util.tree_structure(W0)
    paths = [p for p, _ in trees.tree_paths(W0)]
    flatW = jax.tree_util.tree_leaves(W0)
    flatP = treedef.flatten_up_to(P)
    flatL = jax.tree_util.tree_leaves(levels_tree)
    descs = tuple(
        (path, _ShapeOnly(w.shape), _FrozenProj(_leaf_key(p)), int(lv))
        for path, w, p, lv in zip(paths, flatW, flatP, flatL))
    return _compile_cached(descs, cfg, convention, backend, mesh)


def plan_cache_info():
    """lru_cache stats of the plan memo (tests pin the reuse contract
    — same treedef/shapes/config must NOT recompile)."""
    return _compile_cached.cache_info()


def plan_cache_clear() -> None:
    _compile_cached.cache_clear()
