"""Dense decoder-only transformer (llama/qwen family) + VLM variant.

Parameters are stored **stacked over layers** (leading L axis) and the
forward pass is a ``jax.lax.scan`` over that axis, so compiled-HLO size
is independent of depth (llama3-405b's 126 layers compile like 2).

The VLM family (phi-3-vision backbone) reuses everything here; its stub
vision frontend supplies precomputed patch embeddings which are
projected and prepended to the token embeddings (see DESIGN.md — the
modality frontend is the one allowed stub).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def attn_init(rng, cfg: ModelConfig, n_layers: int):
    d, hd = cfg.d_model, cfg.hd()
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 4)
    p = {
        "wq": _stacked(ks[0], n_layers, d, Hq * hd, cfg),
        "wk": _stacked(ks[1], n_layers, d, Hkv * hd, cfg),
        "wv": _stacked(ks[2], n_layers, d, Hkv * hd, cfg),
        "wo": _stacked(ks[3], n_layers, Hq * hd, d, cfg),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_layers, Hq * hd), cfg.pdtype)
        p["bk"] = jnp.zeros((n_layers, Hkv * hd), cfg.pdtype)
        p["bv"] = jnp.zeros((n_layers, Hkv * hd), cfg.pdtype)
    return p


def _stacked(rng, n_layers, d_in, d_out, cfg: ModelConfig):
    ks = jax.random.split(rng, n_layers)
    return jnp.stack([L.dense_init(k, d_in, d_out, cfg.pdtype) for k in ks])


def mlp_init(rng, cfg: ModelConfig, n_layers: int):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": _stacked(ks[0], n_layers, d, f, cfg),
        "w_up": _stacked(ks[1], n_layers, d, f, cfg),
        "w_down": _stacked(ks[2], n_layers, f, d, cfg),
    }


def init_params(cfg: ModelConfig, rng):
    keys = jax.random.split(rng, 6)
    nL, d = cfg.n_layers, cfg.d_model
    params = {
        "embed": L.embed_init(keys[0], cfg.vocab, d, cfg.pdtype),
        "layers": {
            "ln1": jnp.ones((nL, d), cfg.pdtype),
            "ln2": jnp.ones((nL, d), cfg.pdtype),
            **attn_init(keys[1], cfg, nL),
            **mlp_init(keys[2], cfg, nL),
        },
        "ln_f": jnp.ones((d,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[3], d, cfg.vocab, cfg.pdtype)
    if cfg.family == "vlm":
        params["vision_proj"] = L.dense_init(
            keys[4], cfg.vlm.d_vision, d, cfg.pdtype)
    return params


# --------------------------------------------------------------------------
# per-layer blocks (operate on the scanned per-layer param slice ``lp``)
# --------------------------------------------------------------------------
def _qkv(lp, x, cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.hd()
    q = x @ lp["wq"].astype(cfg.cdtype)
    k = x @ lp["wk"].astype(cfg.cdtype)
    v = x @ lp["wv"].astype(cfg.cdtype)
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(cfg.cdtype)
        k = k + lp["bk"].astype(cfg.cdtype)
        v = v + lp["bv"].astype(cfg.cdtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v


def attn_block(lp, x, positions, cfg: ModelConfig, *, causal=True):
    """Full-sequence self attention (train / prefill)."""
    from repro.sharding import ctx as shard_ctx

    B, S, _ = x.shape
    q, k, v = _qkv(lp, x, cfg)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if cfg.seq_shard and shard_ctx.active():
        # explicit seq->heads reshard (all-to-all) around attention
        # instead of letting GSPMD replicate the S^2 compute (§Perf H4)
        q, k, v = (shard_ctx.constrain_heads(t) for t in (q, k, v))
    o = L.prefill_attention(q, k, v, causal=causal,
                            q_chunk=cfg.attn_chunk_q, k_chunk=cfg.attn_chunk_k,
                            unroll=cfg.unroll_layers,
                            backend=cfg.attn_backend)
    o = o.reshape(B, S, cfg.n_heads * cfg.hd()) @ lp["wo"].astype(cfg.cdtype)
    if cfg.seq_shard and shard_ctx.active():
        o = shard_ctx.constrain_seq(o)
    return o


def attn_block_decode(lp, x, cache, position, cfg: ModelConfig, *,
                      w_live: int | None = None):
    """One-token self attention against a ring-buffer KV cache.

    cache: {"k": (B, W, Hkv, hd), "v": ...}; position: scalar int32
    (lockstep fixed batch) or (B,) int32 per-slot positions (the
    continuous-batching serve loop).  ``w_live`` is the loop's static
    live-slot bound for the cropped decode fast path.
    """
    B, S, _ = x.shape  # S == 1
    q, k, v = _qkv(lp, x, cfg)
    position = jnp.asarray(position, jnp.int32)
    pos = (jnp.full((B, 1), position, jnp.int32) if position.ndim == 0
           else position[:, None])
    q = L.apply_rope(q, pos, cfg.rope_theta)
    k = L.apply_rope(k, pos, cfg.rope_theta)
    cache, valid = L.update_kv_cache(cache, k, v, position)
    o = L.decode_attention(q, cache["k"], cache["v"], valid,
                           backend=cfg.attn_backend, w_live=w_live)
    y = o.reshape(B, 1, cfg.n_heads * cfg.hd()) @ lp["wo"].astype(cfg.cdtype)
    return y, cache


def mlp_block(lp, x, cfg: ModelConfig):
    return L.swiglu(x, lp["w_gate"].astype(cfg.cdtype),
                    lp["w_up"].astype(cfg.cdtype),
                    lp["w_down"].astype(cfg.cdtype))


def layer_fn(lp, x, positions, cfg: ModelConfig):
    x = x + attn_block(lp, L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                       positions, cfg)
    x = x + mlp_block(lp, L.rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
    return x


def layer_fn_decode(lp, x, cache, position, cfg: ModelConfig, *,
                    w_live: int | None = None):
    a, cache = attn_block_decode(lp, L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                                 cache, position, cfg, w_live=w_live)
    x = x + a
    x = x + mlp_block(lp, L.rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
    return x, cache


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------
def embed_inputs(cfg: ModelConfig, params, batch):
    """Token (+ optional patch) embedding.  Returns (x, positions)."""
    tok = params["embed"].astype(cfg.cdtype)[batch["tokens"]]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cfg.cdtype) @ \
            params["vision_proj"].astype(cfg.cdtype)
        x = jnp.concatenate([pe, tok], axis=1)
    else:
        x = tok
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def forward(cfg: ModelConfig, params, batch, mlp_fn=None):
    """Returns logits (B, S, V).  ``mlp_fn`` hook lets MoE reuse this."""
    x, positions = embed_inputs(cfg, params, batch)

    def body(x, lp):
        h = x + attn_block(lp, L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                           positions, cfg)
        fn = mlp_fn or (lambda lp, y: mlp_block(lp, y, cfg))
        h = h + fn(lp, L.rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, None

    body_ = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_, x, params["layers"], unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.cdtype)
    return x @ head


def loss_fn(cfg: ModelConfig, params, batch):
    logits = forward(cfg, params, batch)
    labels, mask = batch["labels"], batch.get("loss_mask")
    if cfg.family == "vlm" and "patch_embeds" in batch:
        # patch positions carry no next-token target
        P = batch["patch_embeds"].shape[1]
        logits = logits[:, P:]
    return L.softmax_xent(logits, labels, mask)


def prefill(cfg: ModelConfig, params, batch, mlp_fn=None):
    """Forward over the prompt, returning (last_logits, kv_cache).

    Only the final position's logits are formed (materialising
    (B, 32k, 128k) logits would be ~34 GB/device); the per-layer K/V
    streams become the decode cache.
    """
    x, positions = embed_inputs(cfg, params, batch)

    def body(x, lp):
        h1 = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        B, S, _ = h1.shape
        q, k, v = _qkv(lp, h1, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o = L.prefill_attention(q, k, v, causal=True,
                                q_chunk=cfg.attn_chunk_q,
                                k_chunk=cfg.attn_chunk_k,
                                unroll=cfg.unroll_layers,
                                backend=cfg.attn_backend)
        a = o.reshape(B, S, cfg.n_heads * cfg.hd()) @ \
            lp["wo"].astype(cfg.cdtype)
        h = x + a
        fn = mlp_fn or (lambda lp, y: mlp_block(lp, y, cfg))
        h = h + fn(lp, L.rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, {"k": k, "v": v}

    body_ = jax.checkpoint(body) if cfg.remat else body
    x, cache = jax.lax.scan(body_, x, params["layers"], unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = L.rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.cdtype)
    return x @ head, cache


# ----- decode -------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, window: int):
    nL, hd = cfg.n_layers, cfg.hd()
    return {
        "k": jnp.zeros((nL, batch, window, cfg.n_kv_heads, hd), cfg.cdtype),
        "v": jnp.zeros((nL, batch, window, cfg.n_kv_heads, hd), cfg.cdtype),
    }


def decode_step(cfg: ModelConfig, params, cache, token, position,
                mlp_fn=None, *, w_live: int | None = None):
    """token: (B, 1) int32; position: scalar int32 (absolute, lockstep)
    or (B,) int32 per-slot positions (continuous batching).

    Returns (logits (B, 1, V), new_cache).  ``w_live`` is the serving
    loop's static live-slot bound (see ``layers.decode_attention``).
    """
    x = params["embed"].astype(cfg.cdtype)[token]

    def body(x, scanned):
        lp, layer_cache = scanned
        a, layer_cache = attn_block_decode(
            lp, L.rms_norm(x, lp["ln1"], cfg.norm_eps), layer_cache,
            position, cfg, w_live=w_live)
        h = x + a
        fn = mlp_fn or (lambda lp, y: mlp_block(lp, y, cfg))
        h = h + fn(lp, L.rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, layer_cache

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache), unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.cdtype)
    return x @ head, new_cache
