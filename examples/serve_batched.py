"""Batched serving of an MA-Echo-aggregated model.

End-to-end: two silos fine-tune, the server aggregates one-shot, and
the aggregate is served with the batched prefill+decode loop — the
"deployment" path of the framework.

  PYTHONPATH=src python examples/serve_batched.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.maecho import MAEchoConfig
from repro.data.synthetic import lm_token_batches
from repro.fl.llm_adapter import aggregate_llm, build_projections
from repro.models.zoo import get_model
from repro.optim import adamw


def main():
    cfg = get_smoke_config("llama3-8b")
    model = get_model(cfg)
    base = model.init_params(jax.random.PRNGKey(0))

    silos, projs = [], []
    for dom in (7, 13):
        params, state = base, adamw(1e-3).init(base)
        opt = adamw(1e-3)
        step = jax.jit(model.make_train_step(opt))
        for t, b in enumerate(lm_token_batches(cfg.vocab, 4, 32, 20,
                                               seed=dom)):
            params, state, _ = step(params, state, b, jnp.int32(t))
        probe = list(lm_token_batches(cfg.vocab, 4, 32, 2, seed=dom))
        silos.append(params)
        projs.append(build_projections(cfg, params, probe))

    global_params = aggregate_llm(cfg, silos, projs,
                                  MAEchoConfig(tau=10, eta=0.5, mu=20.0))
    print("aggregated; serving batched requests…")

    B, P, GEN = 4, 16, 12
    prompts = jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab, (B, P)),
        jnp.int32)
    logits, cache = jax.jit(model.prefill)(global_params,
                                           {"tokens": prompts})
    W = P + GEN
    pad = W - cache["k"].shape[2]
    cache = {k: (jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                 if k in ("k", "v") else v) for k, v in cache.items()}
    serve = jax.jit(model.make_serve_step())
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    outs = [tok]
    for t in range(GEN - 1):
        tok, cache = serve(global_params, cache, tok, jnp.int32(P + t))
        outs.append(tok)
    gen = jnp.concatenate(outs, 1)
    for i in range(B):
        print(f"req{i}: prompt={np.asarray(prompts[i])[:6].tolist()}… "
              f"gen={np.asarray(gen[i]).tolist()}")


if __name__ == "__main__":
    main()
