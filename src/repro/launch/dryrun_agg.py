"""Dry-run of the PAPER'S OP itself: MA-Echo aggregation as a
distributed program on the production mesh.

The server-side Algorithm-1 step over N client checkpoints of an
assigned architecture: V/P stacked over clients (sharded over the
``data`` axis — client-parallel), weight dims sharded over ``model``
exactly like the training params.  Lowered + compiled + roofline'd like
the 40 standard pairs; this is the "most representative of the paper's
technique" hillclimb target in EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m repro.launch.dryrun_agg --arch llama3-8b \
      [--clients 8] [--multipod] \
      [--backend kernel|auto|sharded|sharded2d]

``--backend`` selects the aggregation compute path to compile —
unknown strings are rejected up front with the full choice list
(``core.plan.validate_backend``), never silently routed to a default.
Every run prints a ``[coverage]`` per-backend leaf summary: the
compiled ``AggPlan``'s per-leaf routes (which leaves ride the
kernel / sharded / sharded2d pipelines, which fall back to the
oracle), which is definitionally what the executor runs.

``--sharded-smoke`` instead EXECUTES an 8-way out-dim-sharded
aggregation (``core.maecho`` backend="sharded") on forced host devices
and asserts <1e-3 parity with the single-device oracle — the CI smoke
for the mesh-sharded pipeline:

  REPRO_HOST_DEVICES=8 PYTHONPATH=src \
      python -m repro.launch.dryrun_agg --sharded-smoke

``REPRO_HOST_DEVICES`` (default 512) sets the forced host platform
device count; it must act before the first jax import, hence env var
rather than CLI flag.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_HOST_DEVICES", "512") + " "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.maecho import MAEchoConfig, _maecho_jit  # noqa: E402
from repro.core.plan import compile_plan, validate_backend  # noqa: E402
from repro.fl.llm_adapter import stack_levels_fn  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.zoo import get_model  # noqa: E402
from repro.roofline import analysis as rl  # noqa: E402
from repro.sharding.rules import make_rules  # noqa: E402
from repro.utils import trees  # noqa: E402


def coverage_report(W0, Pp, levels_tree, macfg, backend: str,
                    mesh=None, convention: str = "io") -> dict:
    """Print the per-backend leaf-coverage summary: the compiled
    ``AggPlan``'s per-leaf routes (``core.maecho.dispatch_summary`` is
    a view over the same plan the executor dispatches on), so a leaf
    silently degraded to the oracle is visible instead of buried in a
    trace-time warning.

    Beyond route counts, every leaf gets a detail line with the
    ``LeafPlan`` knobs that decide its memory/collective shape: the
    mesh axes its Gram psums over, the effective sharding tile edge,
    and the client-chunk size (``-`` where the knob is off) — the
    dryrun is the one place those are visible before a 30-min
    production compile."""
    from repro.core.maecho import dispatch_summary

    per_leaf, counts = dispatch_summary(W0, Pp, levels_tree, macfg,
                                        convention, backend, mesh)
    # same memoized plan the executor dispatches on — per-leaf knobs
    plan = compile_plan(W0, Pp, levels_tree, macfg, convention,
                        backend, mesh)
    total = len(per_leaf)
    summary = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(f"[coverage] backend={backend}: {total} leaves ({summary})")
    for lp in plan.leaves:
        axes = ",".join(lp.psum_axes) if lp.psum_axes else "-"
        print(f"[coverage]   {lp.path}: route={lp.route} "
              f"psum_axes={axes} tile={lp.block or '-'} "
              f"chunk={lp.client_chunk or '-'}")
    if backend != "oracle":
        for path, lv, route in per_leaf:
            if route == "oracle":
                print(f"[coverage]   oracle fallback: {path}"
                      f" (stack_levels={lv})")
    return counts


def build_agg(arch: str, n_clients: int, mesh, tau: int,
              rank: int = 0, backend: str = "oracle",
              agg_mesh=None):
    cfg = get_config(arch)
    model = get_model(cfg)
    rules = make_rules(mesh, cfg)
    levels_fn = stack_levels_fn(cfg)
    pspecs = model.param_specs()
    sds = jax.ShapeDtypeStruct

    def v_spec(path, leaf):
        return sds((n_clients,) + leaf.shape, jnp.float32)

    def p_spec(path, leaf):
        lv = levels_fn(path)
        lead = leaf.shape[:lv]
        if path == "embed":
            return sds((n_clients,) + (leaf.shape[0],), jnp.float32)
        if leaf.ndim - lv == 2:       # matmul weight: full projector
            d_in = leaf.shape[lv]     # "io" convention
            if rank:                  # factored P = U diag(s) U^T (H3)
                k = min(rank, d_in)
                return {"U": sds((n_clients,) + lead + (d_in, k),
                                 jnp.float32),
                        "s": sds((n_clients,) + lead + (k,),
                                 jnp.float32)}
            return sds((n_clients,) + lead + (d_in, d_in), jnp.float32)
        return sds((n_clients,) + lead, jnp.float32)  # scalar rule

    W0 = trees.tree_map(lambda l: sds(l.shape, jnp.float32), pspecs)
    V0 = trees.map_with_path(v_spec, pspecs)
    Pp = trees.map_with_path(p_spec, pspecs)

    def w_sh(path, leaf):
        return NamedSharding(mesh, rules.param_spec(path, leaf.shape))

    def v_sh(path, leaf):
        base = rules.param_spec(path, leaf.shape[1:])
        return NamedSharding(mesh, P(*(("data",) + tuple(base))))

    def p_sh(path, leaf):
        if path.endswith(".U") and leaf.ndim >= 3:
            mids = (None,) * (leaf.ndim - 3)
            spec = ("data",) + mids + (
                "model" if leaf.shape[-2] % 16 == 0 else None, None)
            return NamedSharding(mesh, P(*spec))
        if not path.endswith((".U", ".s")) and leaf.ndim >= 3:
            mids = (None,) * (leaf.ndim - 3)
            spec = ("data",) + mids + (
                "model" if leaf.shape[-2] % 16 == 0 else None, None)
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P(*(("data",) +
                                       (None,) * (leaf.ndim - 1))))

    shardings = (trees.map_with_path(w_sh, W0),
                 trees.map_with_path(v_sh, V0),
                 trees.map_with_path(p_sh, Pp))

    macfg = MAEchoConfig(tau=tau, eta=0.5, qp_iters=50)
    levels_tree = trees.map_with_path(lambda p, _: levels_fn(p), W0)
    plan = compile_plan(W0, Pp, levels_tree, macfg, "io", backend,
                        agg_mesh)

    def step(W, V, Pr):
        return _maecho_jit(W, V, Pr, macfg, "io", plan, agg_mesh)

    return step, (W0, V0, Pp), shardings, cfg, (macfg, levels_tree)


def run(arch: str, n_clients: int, multi_pod: bool,
        out_dir: str = "experiments/dryrun", rank: int = 0,
        backend: str = "oracle") -> dict:
    # reject typo'd backends up front (with the full choice list)
    # instead of letting them fall through to a default route — the
    # CLI's argparse `choices` guards the flag, this guards callers
    validate_backend(backend)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    agg_mesh = mesh if backend in ("sharded", "sharded2d") else None
    tag = f"aggregate_N{n_clients}" + (f"_rank{rank}" if rank else "")
    rec = {"arch": arch, "shape": tag,
           "mesh": mesh_name, "status": "ok", "kind": "aggregate",
           "rank": rank, "backend": backend}
    t0 = time.time()
    try:
        costs = {}
        for tau in (1, 2):
            step, args, sh, cfg, (macfg, levels_tree) = build_agg(
                arch, n_clients, mesh, tau, rank, backend, agg_mesh)
            if tau == 1:
                rec["coverage"] = coverage_report(
                    args[0], args[2], levels_tree, macfg, backend,
                    agg_mesh)
            with mesh:
                compiled = jax.jit(
                    step, in_shardings=sh).lower(*args).compile()
            cost = compiled.cost_analysis()
            coll = rl.collective_bytes(compiled.as_text())
            costs[tau] = (float(cost.get("flops", 0)),
                          float(cost.get("bytes accessed", 0)),
                          float(coll["weighted_total"]))
            if tau == 2:
                mem = compiled.memory_analysis()
        per_iter = [costs[2][i] - costs[1][i] for i in range(3)]
        total_tau = 30
        tot = [costs[1][i] + per_iter[i] * (total_tau - 1)
               for i in range(3)]
        chips = mesh.devices.size
        # "model flops" for the op: the Eq.7 GEMM chain = 2·Σ_l N·out·in²
        n_p = get_config(arch).n_params()
        rec.update({
            "compile_s": round(time.time() - t0, 1),
            "tau": total_tau,
            "per_iter": {"flops": per_iter[0], "bytes": per_iter[1],
                         "coll": per_iter[2]},
            "total": {"flops": tot[0], "bytes": tot[1], "coll": tot[2]},
            "memory": {"argument_bytes": getattr(
                mem, "argument_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None)},
            "roofline": {
                "t_compute": tot[0] / rl.PEAK_FLOPS,
                "t_memory_hlo": tot[1] / rl.HBM_BW,
                "t_collective": tot[2] / chips / rl.ICI_BW,
                "chips": chips, "n_clients": n_clients,
            },
        })
        b = rec["roofline"]
        b["bottleneck"] = max(
            [("compute", b["t_compute"]),
             ("memory", b["t_memory_hlo"]),
             ("collective", b["t_collective"])], key=lambda kv: kv[1])[0]
        print(f"[ok] aggregate {arch} N={n_clients} {mesh_name} "
              f"compile {rec['compile_s']}s "
              f"bottleneck={b['bottleneck']} "
              f"t=({b['t_compute']:.2f},{b['t_memory_hlo']:.2f},"
              f"{b['t_collective']:.2f})s")
    except Exception as e:  # noqa: BLE001
        rec.update({"status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-1500:]})
        print(f"[FAIL] aggregate {arch}: {type(e).__name__}: {e}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(
            out_dir, f"{arch}_{tag}_{mesh_name}.json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def run_sharded_smoke(n_devices: int = 8, out_d: int = 1024,
                      in_d: int = 256, n_clients: int = 4,
                      tau: int = 2) -> dict:
    """Execute (not just compile) an ``n_devices``-way out-dim-sharded
    aggregation and check parity against the single-device oracle.

    A mixed tree — dense, factored and diagonal projectors, a
    non-divisible leaf exercising the single-device fallback, a bias
    on the scalar rule, and a scan-over-layers stacked leaf whose
    layer axis rides the kernel grid (one (L, N, N) psum per outer
    iteration) — so one run covers every dispatch branch of
    ``backend="sharded"``.  Returns the record; parity must be <1e-3
    in weight space (the ISSUE acceptance bound).
    """
    from repro.core.maecho import MAEchoConfig, maecho_aggregate
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh(n_devices, 1)
    odd = 2 * (out_d // n_devices) + 64        # tiles don't divide
    n_stack = 3                                # scanned layers
    clients, projs = [], []
    for i in range(n_clients):
        k = jax.random.PRNGKey(31 * i + 7)
        kd, kf, kg, kb = (jax.random.fold_in(k, t) for t in range(4))
        U = jnp.linalg.qr(jax.random.normal(kf, (in_d, 32)))[0]
        s = jax.random.uniform(jax.random.fold_in(kf, 1), (32,))
        Ud = jnp.linalg.qr(jax.random.normal(kd, (in_d, 16)))[0]
        sd = jax.random.uniform(jax.random.fold_in(kd, 1), (16,))
        ks = jax.random.fold_in(k, 9)
        Us = jnp.linalg.qr(jax.random.normal(ks,
                                             (n_stack, in_d, 16)))[0]
        ss = jax.random.uniform(jax.random.fold_in(ks, 1),
                                (n_stack, 16))
        clients.append({
            "dense": jax.random.normal(kd, (out_d, in_d)) * 0.3,
            "fact": jax.random.normal(kf, (out_d, in_d)) * 0.3,
            "diag": jax.random.normal(kg, (out_d, in_d)) * 0.3,
            "odd": jax.random.normal(jax.random.fold_in(kg, 2),
                                     (odd, in_d)) * 0.3,
            "stack": jax.random.normal(jax.random.fold_in(ks, 2),
                                       (n_stack, out_d, in_d)) * 0.3,
            "b": jax.random.normal(kb, (out_d,)) * 0.1,
        })
        projs.append({
            "dense": (Ud * sd) @ Ud.T,
            "fact": {"U": U, "s": s},
            "diag": jax.random.uniform(jax.random.fold_in(kg, 1),
                                       (in_d,)),
            "odd": (Ud * sd) @ Ud.T,
            "stack": jnp.einsum("lik,lk,ljk->lij", Us, ss, Us),
            "b": jnp.ones(()),
        })
    levels = {k: (1 if k == "stack" else 0) for k in clients[0]}
    cfg = MAEchoConfig(tau=tau, eta=0.5, qp_iters=60)
    from repro.utils import trees as _trees
    coverage_report(clients[0],
                    _trees.tree_map(lambda *xs: jnp.stack(xs, 0),
                                    *projs),
                    levels, cfg, "sharded", mesh, convention="oi")
    t0 = time.time()
    a = maecho_aggregate(clients, projs, cfg, backend="oracle",
                         stack_levels=levels)
    b = maecho_aggregate(clients, projs, cfg, backend="sharded",
                         mesh=mesh, stack_levels=levels)
    err = max(float(jnp.max(jnp.abs(a[key] - b[key]))) for key in a)
    ok = err < 1e-3
    rec = {"kind": "sharded_smoke", "devices": n_devices,
           "out_d": out_d, "in_d": in_d, "n_clients": n_clients,
           "tau": tau, "max_abs_err": err,
           "status": "ok" if ok else "PARITY_FAIL",
           "elapsed_s": round(time.time() - t0, 1)}
    print(f"[{'ok' if ok else 'FAIL'}] sharded smoke: {n_devices} "
          f"devices, out={out_d} (+{odd} fallback leaf, "
          f"+{n_stack}-layer stacked leaf), "
          f"max|sharded - oracle| = {err:.2e} "
          f"({rec['elapsed_s']}s)")
    err2d, counts2d, cov_ok = run_sharded2d_smoke(
        n_devices, tau=tau, n_clients=n_clients)
    rec["max_abs_err_2d"] = err2d
    rec["coverage_2d"] = counts2d
    if err2d >= 1e-3:
        rec["status"] = "PARITY_FAIL_2D"
    elif not cov_ok:
        # parity held but the expected routes didn't run — a routing
        # regression, reported as such (not as a phantom numeric one)
        rec["status"] = "COVERAGE_FAIL_2D"
    return rec


def run_sharded2d_smoke(n_devices: int = 8, tau: int = 2,
                        n_clients: int = 4):
    """The 2-D (out × in) half of the smoke: execute
    ``backend="sharded2d"`` on a factored (n_data × n_model) mesh of
    the same forced host devices and check <1e-3 parity against the
    single-device oracle.

    The tree carries the acceptance case: a "thin" leaf whose out-dim
    (2 tiles) CANNOT span the ``n_devices``-way fleet under the 1-D
    out-dim shard (``ops.sharded_ok`` rejects it) but aggregates
    sharded under the 2-D plan because the fleet factors as
    out_axes × in_axes — plus a wide leaf, a stacked leaf riding the
    2-D shard, an in-ragged leaf exercising the sharded2d → sharded
    fallback chain, and a bias on the oracle rule.  Returns
    ``(max_abs_err, coverage_counts, coverage_ok)`` — parity and
    route coverage are reported separately so a red smoke names the
    regression that actually happened.
    """
    from repro.core.maecho import MAEchoConfig, maecho_aggregate
    from repro.kernels import ops
    from repro.launch.mesh import make_debug_mesh
    from repro.sharding.rules import sharded_ok2d
    from repro.utils import trees as _trees

    n_model = (4 if (n_devices % 4 == 0 and n_devices >= 8)
               else 2 if n_devices >= 2 else 1)
    n_data = max(1, n_devices // n_model)
    mesh2d = make_debug_mesh(n_data, n_model)
    in2 = 128 * n_model          # in-tiles span the model axis exactly
    thin_out = 256               # 2 tiles: 1-D over a big fleet fails
    # the fleet-spanning demo needs the thin leaf 1-D-ineligible over
    # the WHOLE fleet yet 2-D-eligible over the factored grid — true
    # at the CI device counts (4 and 8); other counts (e.g. 2, where
    # 2 tiles 1-D-shard fine, or 6, where n_data=3 doesn't divide
    # them) still run the parity but without the premise claim
    fleet_demo = (not ops.sharded_ok(thin_out, in2, n_devices)
                  and sharded_ok2d(thin_out, in2, n_data, n_model))
    if n_devices in (4, 8):
        assert fleet_demo, (
            "smoke premise broken: the thin leaf must be "
            "1-D-ineligible over the fleet and pass the 2-D gate")
    L = 2
    clients, projs = [], []
    for i in range(n_clients):
        k = jax.random.PRNGKey(101 * i + 5)
        kw, kt, ks, kr, kb = (jax.random.fold_in(k, t)
                              for t in range(5))
        Uw = jnp.linalg.qr(jax.random.normal(kw, (in2, 24)))[0]
        sw = jax.random.uniform(jax.random.fold_in(kw, 1), (24,))
        Ut = jnp.linalg.qr(jax.random.normal(kt, (in2, 16)))[0]
        st = jax.random.uniform(jax.random.fold_in(kt, 1), (16,))
        Us = jnp.linalg.qr(jax.random.normal(ks, (L, in2, 16)))[0]
        ss = jax.random.uniform(jax.random.fold_in(ks, 1), (L, 16))
        clients.append({
            "wide": jax.random.normal(kw, (1024, in2)) * 0.3,
            "thin": jax.random.normal(kt, (thin_out, in2)) * 0.3,
            "stack": jax.random.normal(jax.random.fold_in(ks, 2),
                                       (L, 512, in2)) * 0.3,
            "ragged_in": jax.random.normal(kr, (1024, 320)) * 0.3,
            "b": jax.random.normal(kb, (thin_out,)) * 0.1,
        })
        projs.append({
            "wide": (Uw * sw) @ Uw.T,
            "thin": {"U": Ut, "s": st},
            "stack": jnp.einsum("lik,lk,ljk->lij", Us, ss, Us),
            "ragged_in": jax.random.uniform(
                jax.random.fold_in(kr, 1), (320,)),
            "b": jnp.ones(()),
        })
    levels = {key: (1 if key == "stack" else 0) for key in clients[0]}
    cfg = MAEchoConfig(tau=tau, eta=0.5, qp_iters=60)
    counts = coverage_report(
        clients[0],
        _trees.tree_map(lambda *xs: jnp.stack(xs, 0), *projs),
        levels, cfg, "sharded2d", mesh2d, convention="oi")
    t0 = time.time()
    a = maecho_aggregate(clients, projs, cfg, backend="oracle",
                         stack_levels=levels)
    b = maecho_aggregate(clients, projs, cfg, backend="sharded2d",
                         mesh=mesh2d, stack_levels=levels)
    err = max(float(jnp.max(jnp.abs(a[key] - b[key]))) for key in a)
    cov_ok = (counts.get("sharded2d", 0) >= 3 or not fleet_demo)
    ok = err < 1e-3 and cov_ok
    note = (f"thin out={thin_out} (1-D-ineligible over {n_devices})"
            if fleet_demo else f"thin out={thin_out}")
    print(f"[{'ok' if ok else 'FAIL'}] sharded2d smoke: "
          f"{n_data}x{n_model} mesh, {note}, "
          f"max|sharded2d - oracle| = {err:.2e} "
          f"({round(time.time() - t0, 1)}s)")
    return err, counts, cov_ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--rank", type=int, default=0,
                    help="factored-P rank (0 = full projectors)")
    ap.add_argument("--backend", default="oracle",
                    choices=["oracle", "kernel", "auto", "sharded",
                             "sharded2d"],
                    help="aggregation compute path to compile + "
                         "report leaf coverage for (unknown values "
                         "are rejected, never silently defaulted)")
    ap.add_argument("--sharded-smoke", action="store_true",
                    help="execute an 8-way sharded aggregation and "
                         "assert parity with the oracle (set "
                         "REPRO_HOST_DEVICES=8)")
    ap.add_argument("--smoke-devices", type=int, default=8)
    args = ap.parse_args()
    if args.sharded_smoke:
        rec = run_sharded_smoke(args.smoke_devices)
        raise SystemExit(0 if rec["status"] == "ok" else 1)
    rec = run(args.arch, args.clients, args.multipod, rank=args.rank,
              backend=args.backend)
    raise SystemExit(0 if rec["status"] == "ok" else 1)


if __name__ == "__main__":
    main()
