"""Serving benchmarks: dense full-window decode attention (oracle) vs
the Pallas ring-buffer kernel with the bucketed live-window crop — the
decode fast path the serve loop rides — plus an end-to-end serve-step
pair on the qwen2-0.5b smoke model.

Micro rows fix the live fill at 256 slots and sweep the ring-buffer
window W: the oracle pays O(W) per token while the cropped kernel pays
O(live bucket), which is the serving regime (large context budget,
mostly-empty cache).  Parity between the two paths is asserted on
every row; ``derived`` records the effective Pallas interpret flag and
the crop actually applied, so a trajectory point is interpretable
without knowing the machine it ran on.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.kernels import ops
from repro.kernels.env import interpret_default
from repro.models import layers as L


def _decode_attn_rows(quick: bool, interp: bool) -> None:
    B, Hq, Hkv, D = 8, 16, 4, 64
    fill = 256
    windows = (256, 1024) if quick else (256, 1024, 4096)
    k0 = jax.random.PRNGKey(0)
    for W in windows:
        ks = jax.random.split(jax.random.fold_in(k0, W), 3)
        q = jax.random.normal(ks[0], (B, 1, Hq, D), jnp.float32)
        kc = jax.random.normal(ks[1], (B, W, Hkv, D), jnp.float32)
        vc = jax.random.normal(ks[2], (B, W, Hkv, D), jnp.float32)
        live = min(fill, W)
        valid = jnp.broadcast_to(jnp.arange(W)[None, :] < live, (B, W))

        oracle = jax.jit(L.decode_attention_oracle)
        kern = jax.jit(functools.partial(ops.decode_attention_auto,
                                         w_live=live))
        ref_out = oracle(q, kc, vc, valid)
        got = kern(q, kc, vc, valid)
        ok = np.allclose(np.asarray(got), np.asarray(ref_out),
                         atol=1e-4)
        assert ok, f"decode parity failed at W={W}"

        _, us_o = timed(oracle, q, kc, vc, valid)
        _, us_k = timed(kern, q, kc, vc, valid)
        wl = ops.live_window(live, W)
        row(f"serve/decode_attn_oracle_W{W}", us_o,
            f"interpret={interp} fill={live}")
        row(f"serve/decode_attn_kernel_W{W}", us_k,
            f"parity={ok} interpret={interp} w_live={wl} "
            f"speedup={us_o / max(us_k, 1e-9):.1f}x")


def _serve_step_rows(quick: bool, interp: bool) -> None:
    """Per-token decode latency of the full qwen2-0.5b smoke serve
    step at a mostly-empty context budget, oracle vs auto backend."""
    from repro.configs import get_smoke_config
    from repro.launch.serve import live_bucket, pad_kv_to_window
    from repro.models.zoo import get_model

    Bm, P = 4, 200
    window = 512 if quick else 4096
    steps = 8 if quick else 24
    cfg0 = get_smoke_config("qwen2-0.5b")
    rng = np.random.RandomState(0)
    prompts = jnp.asarray(rng.randint(0, cfg0.vocab, size=(Bm, P)),
                          jnp.int32)

    toks_by_backend = {}
    for backend in ("oracle", "auto"):
        cfg = cfg0.replace(attn_backend=backend)
        model = get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        logits, cache = jax.jit(model.prefill)(
            params, {"tokens": prompts})
        cache = pad_kv_to_window(cache, window)
        serve_step = jax.jit(model.make_serve_step(),
                             static_argnames=("w_live",))
        token = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        wl = live_bucket(P + steps + 1, window)
        # warm the single (shape, w_live) variant the loop uses
        tok, c = serve_step(params, cache, token, jnp.int32(P),
                            w_live=wl)
        jax.block_until_ready(tok)
        toks = [int(token[0, 0]), int(tok[0, 0])]
        t0 = time.time()
        for t in range(steps):
            tok, c = serve_step(params, c, tok, jnp.int32(P + 1 + t),
                                w_live=wl)
            toks.append(int(tok[0, 0]))
        jax.block_until_ready(tok)
        us = (time.time() - t0) / steps * 1e6
        toks_by_backend[backend] = toks
        tok_s = Bm / (us / 1e6)
        row(f"serve/serve_step_{backend}", us,
            f"tok_s={tok_s:.0f} window={window} w_live={wl} "
            f"interpret={interp}")
    assert toks_by_backend["oracle"] == toks_by_backend["auto"], \
        "serve-step backends diverged token-wise"


def run(quick: bool = False):
    interp = interpret_default()
    _decode_attn_rows(quick, interp)
    _serve_step_rows(quick, interp)


if __name__ == "__main__":
    run()
