"""Batched multi-leaf QP (ISSUE 2 tentpole): ragged stacked solves,
masked projection, batched-vs-sequential aggregation parity, and the
one-solve-per-outer-iteration contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import projections as proj
from repro.core import qp as qp_mod
from repro.core.maecho import MAEchoConfig, maecho_aggregate
from repro.core.qp import (project_capped_simplex, solve_qp,
                           solve_qp_batched, stack_grams)


def _psd(n, d, seed):
    A = np.random.RandomState(seed).randn(n, d).astype(np.float32)
    return jnp.asarray(A @ A.T)


# --------------------------------------------------------------------------
# solver-level: masked projection and ragged batches
# --------------------------------------------------------------------------
def test_masked_projection_matches_dense():
    """A masked projection over the valid prefix equals the unmasked
    projection of that prefix; padding stays exactly zero."""
    r = np.random.RandomState(3)
    for n in (2, 3, 5):
        x = r.randn(8).astype(np.float32) * 2
        mask = jnp.arange(8) < n
        got = np.asarray(project_capped_simplex(
            jnp.asarray(x), 0.7, mask=mask))
        want = np.asarray(project_capped_simplex(
            jnp.asarray(x[:n]), 0.7))
        np.testing.assert_allclose(got[:n], want, atol=1e-5)
        assert np.all(got[n:] == 0.0)


@pytest.mark.parametrize("C", [1.0, 0.5])
def test_batched_matches_sequential_ragged(C):
    """One stacked solve over ragged sizes N ∈ {2, 3, 8} matches three
    sequential ``solve_qp`` calls to <1e-3 each, with exact zeros on
    the padded coordinates."""
    grams = [_psd(n, 2 * n, seed=10 + n) for n in (2, 3, 8)]
    G, n_valid = stack_grams(grams)
    assert G.shape == (3, 8, 8)
    assert list(np.asarray(n_valid)) == [2, 3, 8]
    alphas = solve_qp_batched(G, C, iters=300, n_valid=n_valid)
    for i, g in enumerate(grams):
        n = g.shape[0]
        ref = np.asarray(solve_qp(g, C, iters=300))
        got = np.asarray(alphas[i])
        np.testing.assert_allclose(got[:n], ref, atol=1e-3)
        assert np.all(got[n:] == 0.0)
        assert abs(got.sum() - 1.0) < 1e-4


def test_batched_arbitrary_mask_matches_subset():
    """A non-prefix boolean mask equals solving the subset QP — the
    ragged-participation contract; masked coordinates stay exactly 0."""
    G = _psd(6, 12, seed=5)
    keep = np.array([True, False, True, True, False, True])
    alphas = solve_qp_batched(G[None], 0.8, iters=300,
                              mask=jnp.asarray(keep)[None])
    got = np.asarray(alphas[0])
    sub = np.asarray(solve_qp(G[np.ix_(keep, keep)], 0.8, iters=300))
    np.testing.assert_allclose(got[keep], sub, atol=1e-3)
    assert np.all(got[~keep] == 0.0)
    assert abs(got.sum() - 1.0) < 1e-4


# --------------------------------------------------------------------------
# ragged client participation through maecho_aggregate
# --------------------------------------------------------------------------
@pytest.mark.parametrize("qp_batched", [True, False])
def test_client_mask_matches_subset_aggregation(qp_batched):
    """client_mask = aggregating the participating subset alone (same
    init point), on both QP paths; non-participants' anchors frozen."""
    from repro.core.maecho import init_global

    clients = _clients(4, shape=(12, 6), seed0=21)
    projs = _projs("full", 4, d=6, seed0=300)
    keep = [0, 2, 3]
    mask = jnp.asarray([i in keep for i in range(4)])
    cfg = MAEchoConfig(tau=5, eta=0.5, qp_iters=120,
                       qp_batched=qp_batched)
    W0 = init_global(clients, "average")
    masked, V = maecho_aggregate(clients, projs, cfg, init_point=W0,
                                 client_mask=mask, return_anchors=True)
    subset = maecho_aggregate([clients[i] for i in keep],
                              [projs[i] for i in keep], cfg,
                              init_point=W0)
    for leaf in ("W", "b"):
        np.testing.assert_allclose(np.asarray(masked[leaf]),
                                   np.asarray(subset[leaf]), atol=1e-3)
    # the masked-out client's anchor never moved
    np.testing.assert_array_equal(np.asarray(V["W"][1]),
                                  np.asarray(clients[1]["W"]))


def test_client_mask_per_leaf_pytree():
    """A per-leaf mask pytree applies a different client subset to
    each leaf (here: all-in for W, a subset for b)."""
    clients = _clients(3, shape=(10, 5), seed0=41)
    projs = _projs("diag", 3, d=5, seed0=500)
    cfg = MAEchoConfig(tau=4, eta=0.5, qp_iters=100)
    mask_tree = {"W": jnp.asarray([True, True, True]),
                 "b": jnp.asarray([True, False, True])}
    out = maecho_aggregate(clients, projs, cfg, client_mask=mask_tree)
    all_in = maecho_aggregate(clients, projs, cfg)
    # W saw every client -> identical to the unmasked run
    np.testing.assert_allclose(np.asarray(out["W"]),
                               np.asarray(all_in["W"]), atol=1e-5)
    # b didn't -> must differ from the unmasked run
    assert float(jnp.max(jnp.abs(out["b"] - all_in["b"]))) > 1e-6


def test_client_mask_bad_shape_raises():
    clients = _clients(3, shape=(8, 4), seed0=61)
    with pytest.raises(ValueError, match=r"client_mask"):
        maecho_aggregate(clients, None, MAEchoConfig(tau=1),
                         client_mask=jnp.asarray([True, False]))


def test_client_mask_all_false_raises():
    """An empty participant set is an upstream bug, not a silent
    no-op aggregation."""
    clients = _clients(3, shape=(8, 4), seed0=71)
    with pytest.raises(ValueError, match=r"at least one participant"):
        maecho_aggregate(clients, None, MAEchoConfig(tau=1),
                         client_mask=jnp.zeros(3, bool))


def test_stack_grams_flattens_leading_axes():
    """Stacked-layer gram blocks (L, N, N) flatten into the QP axis."""
    a = jnp.stack([_psd(4, 6, 0), _psd(4, 6, 1)])      # (2, 4, 4)
    b = _psd(3, 5, 2)                                  # (3, 3)
    G, n_valid = stack_grams([a, b])
    assert G.shape == (3, 4, 4)
    assert list(np.asarray(n_valid)) == [4, 4, 3]
    np.testing.assert_allclose(np.asarray(G[0]), np.asarray(a[0]))
    np.testing.assert_allclose(np.asarray(G[2, :3, :3]), np.asarray(b))
    assert np.all(np.asarray(G[2, 3:, :]) == 0.0)


# --------------------------------------------------------------------------
# aggregation-level: batched path ≡ sequential path
# --------------------------------------------------------------------------
def _clients(n, shape=(6, 4), seed0=0):
    out = []
    for i in range(n):
        k = jax.random.PRNGKey(seed0 + i)
        out.append({"W": jax.random.normal(k, shape),
                    "b": jax.random.normal(jax.random.fold_in(k, 1),
                                           (shape[0],))})
    return out


def _projs(kind, n, d=4, seed0=100):
    ps = []
    for i in range(n):
        k = jax.random.PRNGKey(seed0 + i)
        if kind == "scalar":
            ps.append({"W": jnp.ones(()), "b": jnp.ones(())})
        elif kind == "diag":
            ps.append({"W": (jax.random.uniform(k, (d,)) > 0.4)
                       .astype(jnp.float32), "b": jnp.ones(())})
        elif kind == "full":
            X = jax.random.normal(k, (12, d))
            ps.append({"W": proj.projection_from_features(X, 1e-3),
                       "b": jnp.ones(())})
        else:                                   # factored
            X = jax.random.normal(k, (12, d))
            P = proj.projection_from_features(X, 1e-3)
            ps.append({"W": proj.factor_projection(P, d),
                       "b": jnp.ones(())})
    return ps


@pytest.mark.parametrize("kind", ["scalar", "diag", "full", "factored"])
def test_batched_aggregation_matches_sequential(kind):
    """qp_batched=True reproduces the per-leaf sequential solver to
    <1e-3 for every projector kind."""
    clients = _clients(3)
    projs = _projs(kind, 3)
    cfg = MAEchoConfig(tau=8, eta=0.5)
    wb = maecho_aggregate(clients, projs, cfg)
    ws = maecho_aggregate(clients, projs,
                          dataclasses.replace(cfg, qp_batched=False))
    for leaf in ("W", "b"):
        np.testing.assert_allclose(np.asarray(wb[leaf]),
                                   np.asarray(ws[leaf]), atol=1e-3)


def test_batched_aggregation_stacked_leaves():
    """Stacked-layer leaves contribute one QP row per scanned layer
    and still match the sequential path."""
    L = 3
    clients, projs = [], []
    for i in range(2):
        ws = jnp.stack([jax.random.normal(jax.random.PRNGKey(10 * i + l),
                                          (6, 4)) for l in range(L)])
        ps = jnp.stack([proj.projection_from_features(
            jax.random.normal(jax.random.PRNGKey(50 + 10 * i + l),
                              (12, 4)), 1e-3) for l in range(L)])
        clients.append({"W": ws})
        projs.append({"W": ps})
    cfg = MAEchoConfig(tau=6, eta=0.5)
    wb = maecho_aggregate(clients, projs, cfg,
                          stack_levels=lambda path: 1)
    ws = maecho_aggregate(clients, projs,
                          dataclasses.replace(cfg, qp_batched=False),
                          stack_levels=lambda path: 1)
    np.testing.assert_allclose(np.asarray(wb["W"]),
                               np.asarray(ws["W"]), atol=1e-3)


def test_batched_aggregation_kernel_backend():
    """The split gram/apply kernel pipeline rides the same stacked
    solve: backend="kernel" matches the oracle under batching."""
    clients = _clients(3, shape=(40, 32), seed0=7)
    projs = _projs("full", 3, d=32, seed0=200)
    cfg = MAEchoConfig(tau=3, eta=0.5, qp_iters=80)
    wo = maecho_aggregate(clients, projs, cfg, backend="oracle")
    wk = maecho_aggregate(clients, projs, cfg, backend="kernel")
    np.testing.assert_allclose(np.asarray(wo["W"]),
                               np.asarray(wk["W"]), atol=1e-3)


# --------------------------------------------------------------------------
# the contract: ONE PGD solve per outer iteration
# --------------------------------------------------------------------------
def test_one_qp_solve_per_outer_iteration(monkeypatch):
    """An outer iteration over a multi-leaf model issues exactly one
    ``solve_qp_batched`` call carrying every leaf's Gram — not one
    PGD solve per leaf."""
    calls = []
    orig = qp_mod.solve_qp_batched

    def counting(G, C, iters=300, n_valid=None, **kw):
        calls.append(tuple(G.shape))
        return orig(G, C, iters, n_valid, **kw)

    monkeypatch.setattr(qp_mod, "solve_qp_batched", counting)
    # unusual shapes -> guaranteed fresh trace (tau <= 4 unrolls, so
    # trace-time call counts mirror per-iteration runtime solves)
    n_clients, tau = 3, 3
    clients = _clients(n_clients, shape=(9, 7), seed0=31)
    projs = _projs("full", n_clients, d=7, seed0=400)
    maecho_aggregate(clients, projs, MAEchoConfig(tau=tau, eta=0.3))
    assert len(calls) == tau, (
        f"expected one batched solve per outer iteration ({tau}), "
        f"got {len(calls)}")
    # each solve carries both leaves (W and b) of all clients
    assert all(s == (2, n_clients, n_clients) for s in calls)


def test_sequential_path_skips_batched_solver(monkeypatch):
    """qp_batched=False never touches the stacked solver."""
    calls = []
    orig = qp_mod.solve_qp_batched

    def counting(G, C, iters=300, n_valid=None, **kw):
        calls.append(tuple(G.shape))
        return orig(G, C, iters, n_valid, **kw)

    monkeypatch.setattr(qp_mod, "solve_qp_batched", counting)
    clients = _clients(3, shape=(11, 5), seed0=77)
    maecho_aggregate(clients, None,
                     MAEchoConfig(tau=2, eta=0.3, qp_batched=False))
    assert calls == []
