"""Batched serving driver: prefill + decode loop with a request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --requests 8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.zoo import get_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    B, P = args.requests, args.prompt_len
    rng = np.random.RandomState(args.seed)
    prompts = jnp.asarray(rng.randint(0, cfg.vocab, size=(B, P)),
                          jnp.int32)

    window = max(P + args.gen, 2 * cfg.ssm.d_conv if cfg.ssm else 0)
    t0 = time.time()
    if cfg.family in ("ssm", "hybrid"):
        batch = {"tokens": prompts}
        logits, cache = jax.jit(model.prefill)(params, batch)
    elif cfg.family == "encdec":
        batch = {"audio_embeds": jnp.zeros((B, cfg.encdec.enc_seq,
                                            cfg.d_model), cfg.cdtype),
                 "tokens": prompts[:, :min(P, cfg.encdec.dec_seq - args.gen)]}
        logits, cache = jax.jit(model.prefill)(params, batch)
        # pad self-attn cache to the serving window
        pad = window - cache["k"].shape[2]
        if pad > 0:
            cache["k"] = jnp.pad(cache["k"],
                                 ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache["v"] = jnp.pad(cache["v"],
                                 ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        batch = {"tokens": prompts}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (B, cfg.vlm.n_patches, cfg.vlm.d_vision), cfg.cdtype)
        logits, cache = jax.jit(model.prefill)(params, batch)
        pad = window - cache["k"].shape[2]
        if pad > 0:
            cache["k"] = jnp.pad(cache["k"],
                                 ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache["v"] = jnp.pad(cache["v"],
                                 ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    t_prefill = time.time() - t0

    serve_step = jax.jit(model.make_serve_step())
    token = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [token]
    pos0 = P if cfg.family != "vlm" else P + cfg.vlm.n_patches
    t0 = time.time()
    for t in range(args.gen - 1):
        token, cache = serve_step(params, cache, token,
                                  jnp.int32(pos0 + t))
        out_tokens.append(token)
    jax.block_until_ready(token)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    tps = B * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} requests={B} prompt={P} gen={args.gen}")
    print(f"prefill {t_prefill:.2f}s; decode {t_decode:.2f}s "
          f"({tps:.1f} tok/s aggregate)")
    print("sample:", np.asarray(gen[0])[:16].tolist())


if __name__ == "__main__":
    main()
