"""Shared benchmark scaffolding.

Every benchmark mirrors one table/figure of the paper on the synthetic
datasets (offline container — DESIGN.md §1) and emits CSV rows
``name,us_per_call,derived`` where ``derived`` carries the
table-specific metric (usually accuracy).

Rows are also collected in-process so ``benchmarks.run`` can persist
each suite as ``BENCH_<suite>.json`` (run-over-run perf trajectory —
every invocation appends a run entry; set ``REPRO_BENCH_DIR`` to move
them off the repo root).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.data.partition import dirichlet_partition
from repro.data.synthetic import DatasetSpec
from repro.fl import models as pm
from repro.fl.client import (LocalTrainConfig, compute_projections,
                             evaluate_classifier, train_classifier)

# benchmark-scale dataset (kept smaller than the paper's 60k MNIST so
# the whole suite runs on one CPU core; relative orderings preserved)
BENCH_DATA = DatasetSpec("bench", n_train=8000, n_test=1500, latent=24,
                         out_dim=784, seed=0)
MLP = dataclasses.replace(pm.MLP_SPEC, hidden=(200, 100, 50))


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0]) \
        if jax.tree_util.tree_leaves(out) else None
    return out, (time.time() - t0) * 1e6


def train_locals(spec, data, n_clients, beta, *, epochs=6,
                 same_init=False, seed=0, max_steps=0, proj_alpha=1.0,
                 max_samples=1536):
    parts = dirichlet_partition(data["train_y"], n_clients, beta,
                                seed=seed)
    clients, projs, local_accs = [], [], []
    for k, ix in enumerate(parts):
        init_seed = seed if same_init else seed * 100 + k
        p0 = pm.init(spec, jax.random.PRNGKey(init_seed))
        p, _ = train_classifier(
            spec, p0, data["train_x"][ix], data["train_y"][ix],
            LocalTrainConfig(epochs=epochs, max_steps=max_steps,
                             seed=seed + k))
        clients.append(p)
        projs.append(compute_projections(
            spec, p, data["train_x"][ix], alpha=proj_alpha,
            max_samples=max_samples))
        local_accs.append(evaluate_classifier(
            spec, p, data["test_x"], data["test_y"]))
    return parts, clients, projs, float(np.mean(local_accs))


def ensemble_acc(spec, clients, data) -> float:
    from repro.core.aggregators import ensemble_logits
    import jax.numpy as jnp
    x = jnp.asarray(data["test_x"])
    logits = ensemble_logits(
        lambda w, xx: pm.forward(spec, w, xx), clients, x)
    return float(np.mean(np.argmax(np.asarray(logits), -1) ==
                         data["test_y"]))


_ROWS: list[dict] = []


def row(name: str, us: float, derived, peak_bytes=None) -> str:
    """Emit one bench row.  ``peak_bytes`` (optional) records the
    compiled program's peak temp-buffer footprint alongside the time —
    rows carrying it are gated on BOTH metrics by
    ``tools/check_bench_regression.py``; rows without it keep the
    legacy time-only shape."""
    line = f"{name},{us:.0f},{derived}"
    print(line, flush=True)
    entry = {"name": name, "us_per_call": round(us),
             "derived": str(derived)}
    if peak_bytes is not None:
        entry["peak_bytes"] = int(peak_bytes)
    _ROWS.append(entry)
    return line


def drain_rows() -> list[dict]:
    """Return and clear the rows collected since the last drain."""
    out = list(_ROWS)
    _ROWS.clear()
    return out


def persist_rows(suite: str, rows: list[dict], quick: bool) -> str:
    """Append one run entry to BENCH_<suite>.json (perf trajectory).

    Written atomically (temp file + rename); an unreadable existing
    file is preserved as ``<path>.corrupt`` instead of silently
    discarding the trajectory.
    """
    path = os.path.join(os.environ.get("REPRO_BENCH_DIR", "."),
                        f"BENCH_{suite}.json")
    runs = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                loaded = json.load(f)
            runs = loaded["runs"]
            if not isinstance(runs, list):
                raise ValueError("runs is not a list")
        except (OSError, ValueError, KeyError, TypeError):
            runs = []
            os.replace(path, path + ".corrupt")
            print(f"# warning: unreadable {path} moved to "
                  f"{path}.corrupt; starting a fresh trajectory",
                  flush=True)
    runs.append({"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
                 "quick": quick, "rows": rows})
    tmp = path + ".tmp"
    try:
        with open(tmp, "w") as f:
            json.dump({"suite": suite, "runs": runs}, f, indent=1)
        os.replace(tmp, path)
    finally:
        # a failed dump (unserialisable row, full disk) must not leave
        # the half-written temp file behind
        if os.path.exists(tmp):
            os.remove(tmp)
    return path
