"""Tracing-time sharding-constraint context.

The decode path's ring-buffer cache update (dynamic_update_slice at a
runtime slot) leaves GSPMD free to reshard the cache between the update
and the attention read; on the 405B decode baseline it chose full
rematerialisation (~1.1 GB all-gather per layer — see EXPERIMENTS.md
§Perf H2).  Installing :func:`use_rules` during tracing pins the cache
leaves to the rules' sharding on both sides of the update so the DUS
partitions in place.

The context is a no-op when inactive (unit tests, CPU examples).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding

_STATE = threading.local()


@contextlib.contextmanager
def use_rules(rules):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def active() -> bool:
    return getattr(_STATE, "rules", None) is not None


def constrain_heads(x):
    """Pin (B, S, H, hd) activations to head-parallel layout — the
    explicit reshard point for seq-sharded training (§Perf H4): tells
    GSPMD to all-to-all seq↔heads around attention instead of
    replicating the attention compute."""
    rules = getattr(_STATE, "rules", None)
    if rules is None:
        return x
    from repro.sharding.rules import data_axes
    da = data_axes(rules.mesh)
    spec = rules.spec(x.shape, (da, None, "model", None))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def constrain_seq(x):
    """Pin (B, S, d) activations to sequence-parallel layout."""
    rules = getattr(_STATE, "rules", None)
    if rules is None:
        return x
    from repro.sharding.rules import data_axes
    da = data_axes(rules.mesh)
    spec = rules.spec(x.shape, (da, "model", None))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def constrain_lastdim(x):
    """Shard the last dim over ``model`` (batch over data), everything
    else replicated — used to pin decode q to the cache's hd-sharded
    layout so the QK einsum partially contracts instead of gathering K."""
    rules = getattr(_STATE, "rules", None)
    if rules is None:
        return x
    from repro.sharding.rules import data_axes
    da = data_axes(rules.mesh)
    spec = rules.spec(x.shape,
                      (da,) + (None,) * (x.ndim - 2) + ("model",))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def constrain_scores(s):
    """Pin decode attention scores (B, H, 1, W) replicated over the
    model axis: forces GSPMD into partial-contraction + all-reduce of
    the (small) scores instead of all-gathering the (huge) hd-sharded
    KV cache (§Perf H2: 2.1 GB AG/layer -> 0.27 GB AR/layer, and the
    qk/pv matmuls stay 16-way sharded)."""
    rules = getattr(_STATE, "rules", None)
    if rules is None:
        return s
    from repro.sharding.rules import data_axes
    da = data_axes(rules.mesh)
    spec = rules.spec(s.shape, (da,) + (None,) * (s.ndim - 1))
    return jax.lax.with_sharding_constraint(
        s, NamedSharding(rules.mesh, spec))


def constrain_cache(x, name: str):
    """Pin a KV/state cache leaf (per-layer view, no leading L axis)."""
    rules = getattr(_STATE, "rules", None)
    if rules is None:
        return x
    # per-layer leaf: prepend a dummy L dim for the rules' 5-D pattern
    spec = rules.cache_spec(f"cache.{name}", (1,) + x.shape)
    spec = jax.sharding.PartitionSpec(*spec[1:])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))
