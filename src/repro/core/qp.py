"""The dual QP of Eq. 6 — a one-class-SVM-shaped problem:

    min_α  ½ αᵀ G α    s.t.  Σᵢ αᵢ = 1,  0 ≤ αᵢ ≤ C

with G the Gram matrix of the per-client gradients gᵢ = 2 Pᵢ (w − vᵢ).

The paper solves this with CVXOPT on the host.  Here the solver must
*lower* inside a jitted TPU program (the aggregation step is a
first-class distributed op), so we use accelerated projected gradient
descent with an exact O(N log N + iters) projection onto the capped
simplex via bisection.  N ≤ 50 in all experiments; PGD converges to
CVXOPT-level accuracy in a few hundred cheap N×N iterations
(validated in tests/test_qp.py against an active-set reference).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def project_capped_simplex(x, C: float, iters: int = 60):
    """Euclidean projection onto {α : Σα = 1, 0 ≤ α ≤ C}.

    Solves for τ with Σ clip(x − τ, 0, C) = 1 by bisection (monotone
    decreasing in τ); jittable, fixed iteration count.
    """
    x = x.astype(jnp.float32)
    lo = jnp.min(x) - C - 1.0
    hi = jnp.max(x)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        s = jnp.sum(jnp.clip(x - mid, 0.0, C))
        # s > 1 -> tau too small -> raise lo
        lo = jnp.where(s > 1.0, mid, lo)
        hi = jnp.where(s > 1.0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    tau = 0.5 * (lo + hi)
    return jnp.clip(x - tau, 0.0, C)


@partial(jax.jit, static_argnames=("iters",))
def solve_qp(G, C: float, iters: int = 300):
    """Accelerated PGD for min ½αᵀGα on the capped simplex.

    G: (N, N) PSD Gram matrix (any positive rescaling of G gives the
    same minimiser, so callers may pass unscaled residual inner
    products).  Returns α ∈ R^N.
    """
    N = G.shape[0]
    G = G.astype(jnp.float32)
    # Lipschitz bound: row-sum norm (cheap, >= lambda_max for PSD G)
    L = jnp.maximum(jnp.max(jnp.sum(jnp.abs(G), axis=1)), 1e-12)
    step = 1.0 / L
    a0 = jnp.full((N,), 1.0 / N, jnp.float32)
    a0 = project_capped_simplex(a0, C)

    def body(_, state):
        a, y, t = state
        g = G @ y
        a_new = project_capped_simplex(y - step * g, C)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_new = a_new + ((t - 1.0) / t_new) * (a_new - a)
        return a_new, y_new, t_new

    a, _, _ = jax.lax.fori_loop(0, iters, body, (a0, a0, jnp.float32(1.0)))
    return a


def solve_qp_active_set(G, C: float, tol: float = 1e-10,
                        max_iter: int = 1000):
    """Reference dense solver (numpy, Frank-Wolfe with away steps).

    Used in tests as the CVXOPT stand-in oracle for :func:`solve_qp`.
    """
    import numpy as np

    G = np.asarray(G, dtype=np.float64)
    N = G.shape[0]
    a = np.full(N, 1.0 / N)
    a = np.clip(a, 0, C)
    a /= a.sum()
    for _ in range(max_iter):
        g = G @ a
        # FW vertex of the capped simplex: put as much mass as possible
        # on the smallest-gradient coordinates
        order = np.argsort(g)
        s = np.zeros(N)
        rem = 1.0
        for i in order:
            s[i] = min(C, rem)
            rem -= s[i]
            if rem <= 0:
                break
        d = s - a
        gap = -g @ d
        if gap < tol:
            break
        # exact line search on quadratic
        dGd = d @ G @ d
        t = 1.0 if dGd <= 0 else min(1.0, gap / dGd)
        a = a + t * d
    return a
