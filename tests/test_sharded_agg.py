"""Mesh-sharded one-shot aggregation (ISSUE 3 tentpole).

In-process tests run on the real single device (the shard_map path at
axis size 1, eligibility logic against shape-only fake meshes, psum
accounting, the debug-mesh shortfall error).  True multi-device runs
need ``XLA_FLAGS=--xla_force_host_platform_device_count`` set before
jax initializes, which a pytest session can't do retroactively — those
parity/fallback checks subprocess (marked ``slow``).
"""
import dataclasses
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.maecho import MAEchoConfig, maecho_aggregate
from repro.core.plan import leaf_route
from repro.kernels import ops, ref
from repro.launch.mesh import make_debug_mesh

REPO = pathlib.Path(__file__).resolve().parent.parent


class FakeMesh:
    """Shape-only mesh stand-in (cf. tests/test_sharding.py)."""

    def __init__(self, shape: dict):
        self.shape = shape


def _one_device_mesh():
    return Mesh(np.asarray(jax.devices()[:1]), ("data",))


def _proj_of_kind(k, kind, N, in_d, rank=24):
    if kind == "scalar":
        return jax.random.uniform(jax.random.fold_in(k, 2), (N,))
    if kind == "diag":
        return jax.random.uniform(jax.random.fold_in(k, 2), (N, in_d))
    U = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(k, 2),
                                        (N, in_d, min(rank, in_d))))[0]
    s = jax.random.uniform(jax.random.fold_in(k, 3),
                           (N, min(rank, in_d)))
    if kind == "factored":
        return {"U": U, "s": s}
    return jnp.einsum("nik,nk,njk->nij", U, s, U)


# --------------------------------------------------------------------------
# eligibility: the block-granular `_ok` divisibility contract
# --------------------------------------------------------------------------
def test_sharded_ok_divisibility():
    # 1024 = 8 tiles of 128: divides over 1/2/4/8, not 3
    for asz in (1, 2, 4, 8):
        assert ops.sharded_ok(1024, 256, asz)
    assert not ops.sharded_ok(1024, 256, 3)
    # 300 -> 3 tiles: not divisible by 8
    assert not ops.sharded_ok(300, 256, 8)
    assert ops.sharded_ok(300, 256, 3)
    # below one tile on either dim: never sharded
    assert not ops.sharded_ok(64, 256, 1)
    assert not ops.sharded_ok(1024, 64, 8)
    # padding rounds 4000 up to 32 tiles
    assert ops.sharded_ok(4000, 128, 8)


def test_axis_size_of():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert ops.axis_size_of(mesh, "data") == 16
    assert ops.axis_size_of(mesh, ("pod", "data")) == 32
    assert ops.axis_size_of(mesh, "absent") == 1


def test_sharded_route_fallback_paths():
    """The routing rules `_use_sharded` used to encode, now pinned on
    the plan compiler's single copy (``plan.leaf_route``)."""
    mesh = FakeMesh({"data": 8, "model": 1})
    cfg = MAEchoConfig()
    W = jnp.zeros((1024, 256))
    P = jnp.zeros((3, 256, 256))
    def route(w, p, backend, m, conv="oi", c=cfg):
        return leaf_route(w, p, 0, c, conv, backend, m)
    assert route(W, P, "sharded", mesh) == "sharded"
    # io convention: the kernel-layout out-dim is W.shape[1]
    assert route(W.T, P, "sharded", mesh, "io") == "sharded"
    assert route(W.T, P, "sharded", mesh, "oi") != "sharded"
    # non-divisible out, wrong backend, missing mesh, 1-D leaf
    assert route(jnp.zeros((300, 256)), P, "sharded",
                 mesh) == "kernel"
    assert route(W, P, "kernel", mesh) == "kernel"
    assert route(W, P, "sharded", None) == "kernel"
    assert route(jnp.zeros((1024,)), jnp.zeros((3,)), "sharded",
                 mesh) == "oracle"
    # a mesh without the configured axis: fall back, don't KeyError
    assert route(W, P, "sharded", FakeMesh({"x": 8})) == "kernel"
    assert route(W, P, "sharded", mesh,
                 c=dataclasses.replace(
                     cfg, mesh_axis=("pod", "data"))) == "kernel"


def test_sharded_backend_mesh_without_axis_falls_back():
    """A mesh lacking cfg.mesh_axis degrades to the single-device
    path end-to-end instead of crashing inside shard_map."""
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("x",))
    N = 3
    clients = [{"W": jax.random.normal(jax.random.PRNGKey(i),
                                       (256, 140)) * 0.3}
               for i in range(N)]
    projs = [{"W": jax.random.uniform(jax.random.PRNGKey(9 + i),
                                      (140,))}
             for i in range(N)]
    cfg = MAEchoConfig(tau=2, eta=0.5, qp_iters=40)
    a = maecho_aggregate(clients, projs, cfg, backend="oracle")
    b = maecho_aggregate(clients, projs, cfg, backend="sharded",
                         mesh=mesh)
    np.testing.assert_allclose(np.asarray(a["W"]), np.asarray(b["W"]),
                               atol=1e-3)


# --------------------------------------------------------------------------
# single-device mesh: the shard_map path itself (axis size 1)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["scalar", "diag", "full", "factored"])
def test_sharded_gram_apply_parity_one_device(kind):
    N, out_d, in_d = 3, 256, 140          # odd in-dim: padding path
    mesh = _one_device_mesh()
    k = jax.random.PRNGKey(out_d + in_d)
    W = jax.random.normal(k, (out_d, in_d)) * 0.3
    V = jax.random.normal(jax.random.fold_in(k, 1),
                          (N, out_d, in_d)) * 0.3
    P = _proj_of_kind(k, kind, N, in_d)
    alpha = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 9),
                                             (N,)))

    def step(W, V, P):
        G, ctx = ops.maecho_sharded_gram(W, V, P, mesh=mesh,
                                         axis="data")
        Wn, Vn = ops.maecho_sharded_apply(alpha, ctx, mesh=mesh,
                                          axis="data", eta=0.7,
                                          frac=0.5, norm=True)
        return G, Wn, Vn

    G, Wn, Vn = jax.jit(step)(W, V, P)
    Gr = ref.maecho_gram_ref(W, V, P)
    Wr = ref.maecho_update_ref_any(W, V, P, alpha, 0.7)
    Vr = ref.maecho_v_update_ref(Wr, V, P, 0.5, True)
    np.testing.assert_allclose(np.asarray(G), np.asarray(Gr),
                               atol=1e-2, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(Wn), np.asarray(Wr),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(Vn), np.asarray(Vr),
                               atol=1e-4)


@pytest.mark.parametrize("convention", ["oi", "io"])
def test_sharded_backend_aggregate_parity_one_device(convention):
    """backend="sharded" through maecho_aggregate (mixed tree with a
    bias on the oracle fallback) matches the oracle."""
    N = 3
    clients, projs = [], []
    for i in range(N):
        k = jax.random.PRNGKey(11 * i + 3)
        shape = (256, 140) if convention == "oi" else (140, 256)
        clients.append({"W": jax.random.normal(k, shape) * 0.3,
                        "b": jax.random.normal(jax.random.fold_in(k, 1),
                                               (shape[0],)) * 0.1})
        U = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(k, 2),
                                            (140, 16)))[0]
        s = jax.random.uniform(jax.random.fold_in(k, 3), (16,))
        projs.append({"W": (U * s) @ U.T, "b": jnp.ones(())})
    cfg = MAEchoConfig(tau=3, eta=0.5, qp_iters=60)
    a = maecho_aggregate(clients, projs, cfg, convention=convention,
                         backend="oracle")
    b = maecho_aggregate(clients, projs, cfg, convention=convention,
                         backend="sharded", mesh=_one_device_mesh())
    np.testing.assert_allclose(np.asarray(a["W"]), np.asarray(b["W"]),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(a["b"]), np.asarray(b["b"]),
                               atol=1e-3)


def test_exactly_one_psum_per_outer_iteration():
    """The acceptance contract: a sharded leaf costs ONE (N, N) psum
    per outer iteration — the gram reconstruction — and the apply
    phase is collective-free."""
    mesh = _one_device_mesh()
    N, tau = 3, 2
    clients = [{"W": jax.random.normal(jax.random.PRNGKey(i),
                                       (256, 140)) * 0.3}
               for i in range(N)]
    projs = [{"W": jax.random.uniform(jax.random.PRNGKey(50 + i),
                                      (140,))}
             for i in range(N)]
    cfg = MAEchoConfig(tau=tau, eta=0.5, qp_iters=40)
    txt = str(jax.make_jaxpr(
        lambda: maecho_aggregate(clients, projs, cfg,
                                 backend="sharded", mesh=mesh))())
    assert txt.count("psum") == tau, (
        f"expected {tau} psums (one per outer iteration), "
        f"found {txt.count('psum')}")


def test_divisibility_fallback_eligibility():
    """A leaf whose out-dim tiles don't divide the axis is rejected by
    the eligibility check (8-way fake mesh) and the same model still
    aggregates cleanly under backend="sharded" (the real-axis psum-free
    fallback runs in the 8-device subprocess test below)."""
    mesh = FakeMesh({"data": 8, "model": 1})
    real = _one_device_mesh()
    N = 3
    clients = [{"W": jax.random.normal(jax.random.PRNGKey(i),
                                       (300, 140)) * 0.3}
               for i in range(N)]
    projs = [{"W": jax.random.uniform(jax.random.PRNGKey(9 + i),
                                      (140,))}
             for i in range(N)]
    assert leaf_route(clients[0]["W"], jnp.zeros((N, 140)), 0,
                      MAEchoConfig(), "oi", "sharded",
                      mesh) != "sharded"
    cfg = MAEchoConfig(tau=2, eta=0.5, qp_iters=40)
    a = maecho_aggregate(clients, projs, cfg, backend="oracle")
    b = maecho_aggregate(clients, projs, cfg, backend="sharded",
                         mesh=real)
    np.testing.assert_allclose(np.asarray(a["W"]), np.asarray(b["W"]),
                               atol=1e-3)


def test_make_debug_mesh_raises_on_shortfall():
    with pytest.raises(RuntimeError, match=r"needs 4096 devices"):
        make_debug_mesh(64, 64)


def test_agg_partition_specs():
    """The rules' aggregation placement specs: rows over the data
    axes with the `_ok` divisibility fallback, QP inputs replicated —
    congruent with the shard_map layout ops builds inline (W rows on
    dim 0, V rows on dim 1)."""
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.sharding.rules import make_rules

    rules = make_rules(FakeMesh({"pod": 2, "data": 16, "model": 16}),
                       get_config("llama3_8b"))
    assert rules.agg_out_axes(4096) == ("pod", "data")
    assert rules.agg_out_axes(100) is None
    assert rules.agg_weight_spec((4096, 1024)) == P(("pod", "data"),
                                                    None)
    # non-divisible out / 1-D bias: replicated
    assert rules.agg_weight_spec((100, 1024)) == P(None, None)
    assert rules.agg_weight_spec((4096,)) == P(None)
    assert rules.agg_anchor_spec((8, 4096, 1024)) == P(
        None, ("pod", "data"), None)
    assert rules.agg_anchor_spec((8, 4096)) == P(None, None)
    assert rules.agg_proj_spec((8, 1024, 1024)) == P(None, None, None)
    assert rules.agg_gram_spec() == P(None, None)
    assert rules.agg_alpha_spec() == P(None)


# --------------------------------------------------------------------------
# true 8-device runs (fresh process: XLA flag must precede jax init)
# --------------------------------------------------------------------------
def _run_forced(code_or_args, n_devices=8):
    env = {**os.environ,
           "REPRO_HOST_DEVICES": str(n_devices),
           "PYTHONPATH": str(REPO / "src") + os.pathsep
           + os.environ.get("PYTHONPATH", "")}
    env.pop("XLA_FLAGS", None)
    if isinstance(code_or_args, str):
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{n_devices}")
        args = [sys.executable, "-c", code_or_args]
    else:
        args = [sys.executable] + code_or_args
    return subprocess.run(args, cwd=REPO, env=env,
                          capture_output=True, text=True, timeout=900)


@pytest.mark.slow
def test_sharded_smoke_4dev():
    """The smoke CLI at a non-CI axis size (CI's full lane runs the
    same entry point at 8 devices — 4 here keeps the coverage
    distinct instead of paying for the identical run twice)."""
    r = _run_forced(["-m", "repro.launch.dryrun_agg",
                     "--sharded-smoke", "--smoke-devices", "4"],
                    n_devices=4)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[ok] sharded smoke" in r.stdout


@pytest.mark.slow
def test_sharded_parity_kinds_conventions_8dev():
    """Acceptance: 8-way sharded aggregation matches the single-device
    oracle to <1e-3 across projector kinds and weight conventions,
    with exactly one (N, N) psum per outer iteration."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core.maecho import MAEchoConfig, maecho_aggregate
        assert len(jax.devices()) == 8, jax.devices()
        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        N, out_d, in_d, tau = 3, 1024, 256, 2
        cfg = MAEchoConfig(tau=tau, eta=0.5, qp_iters=40)

        def mk(kind, conv):
            cs, ps = [], []
            for i in range(N):
                k = jax.random.PRNGKey(13 * i + 1)
                shape = (out_d, in_d) if conv == "oi" else (in_d, out_d)
                cs.append({"W": jax.random.normal(k, shape) * 0.3})
                if kind == "scalar":
                    pw = jnp.ones(())
                elif kind == "diag":
                    pw = jax.random.uniform(jax.random.fold_in(k, 2),
                                            (in_d,))
                else:
                    U = jnp.linalg.qr(jax.random.normal(
                        jax.random.fold_in(k, 2), (in_d, 24)))[0]
                    s = jax.random.uniform(jax.random.fold_in(k, 3),
                                           (24,))
                    pw = ({"U": U, "s": s} if kind == "factored"
                          else (U * s) @ U.T)
                ps.append({"W": pw})
            return cs, ps

        combos = ([(kind, "oi") for kind in
                   ("scalar", "diag", "full", "factored")]
                  + [("full", "io"), ("factored", "io")])
        for kind, conv in combos:
            cs, ps = mk(kind, conv)
            a = maecho_aggregate(cs, ps, cfg, convention=conv,
                                 backend="oracle")
            b = maecho_aggregate(cs, ps, cfg, convention=conv,
                                 backend="sharded", mesh=mesh)
            err = float(jnp.max(jnp.abs(a["W"] - b["W"])))
            assert err < 1e-3, (kind, conv, err)
            txt = str(jax.make_jaxpr(
                lambda cs=cs, ps=ps, conv=conv: maecho_aggregate(
                    cs, ps, cfg, convention=conv, backend="sharded",
                    mesh=mesh))())
            assert txt.count("psum") == tau, (kind, conv,
                                              txt.count("psum"))
            print(f"ok {kind}/{conv}: err={err:.2e}")
        print("ALL_OK")
    """)
    r = _run_forced(code)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ALL_OK" in r.stdout


@pytest.mark.slow
def test_divisibility_fallback_8dev():
    """out=300 (3 tiles) over 8 devices: no crash, no psum, oracle
    parity — the clean single-device fallback at real axis size."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core.maecho import MAEchoConfig, maecho_aggregate
        assert len(jax.devices()) == 8
        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        N = 3
        cs = [{"W": jax.random.normal(jax.random.PRNGKey(i),
                                      (300, 140)) * 0.3}
              for i in range(N)]
        ps = [{"W": jax.random.uniform(jax.random.PRNGKey(9 + i),
                                       (140,))}
              for i in range(N)]
        cfg = MAEchoConfig(tau=2, eta=0.5, qp_iters=40)
        a = maecho_aggregate(cs, ps, cfg, backend="oracle")
        b = maecho_aggregate(cs, ps, cfg, backend="sharded", mesh=mesh)
        err = float(jnp.max(jnp.abs(a["W"] - b["W"])))
        assert err < 1e-3, err
        txt = str(jax.make_jaxpr(
            lambda: maecho_aggregate(cs, ps, cfg, backend="sharded",
                                     mesh=mesh))())
        assert txt.count("psum") == 0, txt.count("psum")
        print("FALLBACK_OK", err)
    """)
    r = _run_forced(code)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "FALLBACK_OK" in r.stdout
