"""msgpack pytree checkpointing (orbax/flax are not available offline).

Format: a dict {"tree": nested structure with leaf descriptors,
"arrays": list of raw buffers} packed with msgpack; arrays stored as
(dtype, shape, bytes).  Works for every params/opt-state pytree in the
framework, including the FL client/server states.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(tree: Any, arrays: list):
    if isinstance(tree, dict):
        return {"__d": {k: _encode(v, arrays) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__l" if isinstance(tree, list) else "__t":
                [_encode(v, arrays) for v in tree]}
    if isinstance(tree, (jnp.ndarray, np.ndarray)):
        arr = np.asarray(tree)
        arrays.append(arr.tobytes())
        return {"__a": [str(arr.dtype), list(arr.shape)]}
    if isinstance(tree, (int, float, str, bool)) or tree is None:
        return {"__s": tree}
    raise TypeError(f"cannot serialise {type(tree)}")


def _decode(node: Any, arrays: list, idx: list):
    if "__d" in node:
        return {k: _decode(v, arrays, idx) for k, v in node["__d"].items()}
    if "__l" in node:
        return [_decode(v, arrays, idx) for v in node["__l"]]
    if "__t" in node:
        return tuple(_decode(v, arrays, idx) for v in node["__t"])
    if "__a" in node:
        dtype, shape = node["__a"]
        buf = arrays[idx[0]]
        idx[0] += 1
        return jnp.asarray(np.frombuffer(buf, dtype=dtype).reshape(shape))
    return node["__s"]


def save(path: str, tree: Any) -> None:
    arrays: list = []
    enc = _encode(tree, arrays)
    payload = msgpack.packb({"tree": enc, "arrays": arrays},
                            use_bin_type=True)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(payload)
    os.replace(tmp, path)


def load(path: str) -> Any:
    with open(path, "rb") as f:
        obj = msgpack.unpackb(f.read(), raw=False)
    return _decode(obj["tree"], obj["arrays"], [0])
