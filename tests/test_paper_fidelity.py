"""End-to-end paper-fidelity regression (the paper's core claim).

One communication round on a label-disjoint Dirichlet partition
(β = 0.05 — the paper's extreme non-IID setting, §7) through
``run_multi_round`` on the paper MLP: the MA-Echo one-shot aggregate
must beat BOTH the best individually-trained client and FedAvg-style
naive weight averaging on the global test set.  This is Table-1/§7.4's
ordering pinned as a regression test — if a dispatch or QP change
silently degrades the aggregation quality (not just its parity), this
catches it where the unit parity tests cannot.

Margins: the recorded run scores maecho ≈ 0.99, fedavg ≈ 0.83, best
local ≈ 0.66; the assertions keep a ≥0.05 cushion so benign numeric
drift does not flake the suite.
"""
import jax
import pytest

from repro.core.maecho import MAEchoConfig
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import DatasetSpec, generate
from repro.fl import models as pm
from repro.fl.client import (LocalTrainConfig, evaluate_classifier,
                             train_classifier)
from repro.fl.rounds import MultiRoundConfig, run_multi_round


@pytest.mark.slow
def test_one_shot_beats_best_client_and_fedavg():
    data = generate(DatasetSpec("fidelity", n_train=6000, n_test=1200,
                                latent=24, out_dim=784, seed=0))
    parts = dirichlet_partition(data["train_y"], 4, 0.05, seed=1)
    client_data = [(data["train_x"][ix], data["train_y"][ix])
                   for ix in parts]
    test = (data["test_x"], data["test_y"])

    local = LocalTrainConfig(epochs=6, max_steps=200, seed=5)
    common = dict(n_rounds=1, n_clients=4, sample_clients=4,
                  local=local, seed=3)

    # the best single client, trained from run_multi_round's own init
    # point (cfg.seed = 3) so the comparison is init-for-init fair
    init = pm.init(pm.MLP_SPEC, jax.random.PRNGKey(3))
    local_accs = []
    for k in range(4):
        x, y = client_data[k]
        p, _ = train_classifier(pm.MLP_SPEC, init, x, y, local,
                                anchor=init)
        local_accs.append(evaluate_classifier(pm.MLP_SPEC, p, *test))

    _, acc_fedavg = run_multi_round(
        pm.MLP_SPEC, client_data, test,
        MultiRoundConfig(method="fedavg", **common))
    _, acc_maecho = run_multi_round(
        pm.MLP_SPEC, client_data, test,
        MultiRoundConfig(method="maecho",
                         maecho=MAEchoConfig(tau=30, eta=0.5, mu=20.0),
                         **common))

    best_local = max(local_accs)
    assert acc_maecho > best_local + 0.05, (
        f"one-shot MA-Echo ({acc_maecho:.3f}) must beat the best "
        f"single client ({best_local:.3f})")
    assert acc_maecho > acc_fedavg + 0.05, (
        f"one-shot MA-Echo ({acc_maecho:.3f}) must beat naive "
        f"averaging ({acc_fedavg:.3f})")
