"""Compile-once plan/executor layer (ISSUE 5 tentpole): plan caching,
summary/execution anti-drift regression, backend validation, and the
2-D (out × in) sharded route's psum/launch/parity contracts."""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from jax.sharding import Mesh

import strategies as strat
from repro.core import plan as plan_mod
from repro.core.maecho import (MAEchoConfig, dispatch_summary,
                               maecho_aggregate)
from repro.core.plan import compile_plan, leaf_route
from repro.kernels import ops, ref
from repro.sharding.rules import sharded_ok2d


class FakeMesh:
    """Shape-only mesh stand-in (cf. tests/test_sharding.py)."""

    def __init__(self, shape: dict):
        self.shape = shape


def _mesh1d():
    return Mesh(np.asarray(jax.devices()[:1]), ("data",))


def _mesh2d():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


def _model(out_d=256, in_d=256, n=3, kind="diag", lead=()):
    clients, projs = [], []
    for i in range(n):
        k = jax.random.fold_in(jax.random.PRNGKey(out_d + in_d), i)
        clients.append({
            "W": jax.random.normal(k, lead + (out_d, in_d)) * 0.3,
            "b": jax.random.normal(jax.random.fold_in(k, 1),
                                   (out_d,)) * 0.1})
        projs.append({
            "W": strat.make_projector(jax.random.fold_in(k, 2), kind,
                                      lead, in_d),
            "b": jnp.ones(())})
    return clients, projs, {"W": len(lead), "b": 0}


def _stacked_P(projs):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *projs)


# --------------------------------------------------------------------------
# plan compilation + memoization
# --------------------------------------------------------------------------
def test_compile_plan_is_memoized():
    """Same treedef/shapes/config -> the SAME AggPlan object (the
    compile-once contract; the executor's jit cache keys off it)."""
    cfg = MAEchoConfig(qp_iters=123)
    sds = jax.ShapeDtypeStruct
    W0 = {"W": sds((256, 256), jnp.float32)}
    P = {"W": sds((3, 256), jnp.float32)}
    levels = {"W": 0}
    p1 = compile_plan(W0, P, levels, cfg, "oi", "auto", None)
    p2 = compile_plan(W0, P, levels, cfg, "oi", "auto", None)
    assert p1 is p2
    # shapes / cfg / backend each key the cache
    p3 = compile_plan({"W": sds((512, 256), jnp.float32)},
                      P, levels, cfg, "oi", "auto", None)
    assert p3 is not p1
    p4 = compile_plan(W0, P, levels,
                      dataclasses.replace(cfg, qp_iters=7),
                      "oi", "auto", None)
    assert p4 is not p1
    assert p4 == p1          # ...but routing is identical
    assert compile_plan(W0, P, levels, cfg, "oi", "kernel",
                        None) is not p1


def test_aggregate_reuses_compiled_plan():
    """Repeated maecho_aggregate calls over the same model hit the
    plan memo — no recompilation per call (and a fortiori none per
    outer iteration: the τ-loop runs inside one jitted executor)."""
    clients, projs, levels = _model(kind="full")
    cfg = MAEchoConfig(tau=2, eta=0.5, qp_iters=119)
    maecho_aggregate(clients, projs, cfg, stack_levels=levels,
                     backend="auto")
    before = plan_mod.plan_cache_info()
    maecho_aggregate(clients, projs, cfg, stack_levels=levels,
                     backend="auto")
    after = plan_mod.plan_cache_info()
    assert after.hits == before.hits + 1
    assert after.misses == before.misses


def test_plan_leaf_fields():
    """The plan records kernel layout, tile size and psum axes."""
    cfg = MAEchoConfig()
    mesh = FakeMesh({"data": 2, "model": 2})
    sds = jax.ShapeDtypeStruct
    W0 = {"W": sds((512, 256), jnp.float32),
          "b": sds((512,), jnp.float32)}
    P = {"W": sds((3, 256, 256), jnp.float32),
         "b": sds((3,), jnp.float32)}
    plan = compile_plan(W0, P, {"W": 0, "b": 0}, cfg, "oi",
                        "sharded2d", mesh)
    by_path = {lp.path: lp for lp in plan.leaves}
    w = by_path["W"]
    assert w.route == "sharded2d" and w.kind == "full"
    assert (w.out_d, w.in_d) == (512, 256)
    assert w.psum_axes == ("data", "model")
    assert w.out_axes == ("data",) and w.in_axes == ("model",)
    b = by_path["b"]
    assert b.route == "oracle" and b.psum_axes == ()
    # "io" swaps the kernel-layout dims
    plan_io = compile_plan({"W": sds((256, 512), jnp.float32)},
                           {"W": sds((3, 256, 256), jnp.float32)},
                           {"W": 0}, cfg, "io", "sharded2d", mesh)
    assert (plan_io.leaves[0].out_d, plan_io.leaves[0].in_d) == (512,
                                                                 256)


# --------------------------------------------------------------------------
# backend validation: unknown strings never fall through to a default
# --------------------------------------------------------------------------
def test_unknown_backend_rejected_with_choices():
    clients, projs, levels = _model()
    with pytest.raises(ValueError, match="sharded2d"):
        maecho_aggregate(clients, projs, MAEchoConfig(tau=1),
                         backend="warp")
    with pytest.raises(ValueError, match="valid choices"):
        compile_plan(clients[0], _stacked_P(projs), levels,
                     MAEchoConfig(), "oi", "gpu", None)
    with pytest.raises(ValueError, match="valid choices"):
        dispatch_summary(clients[0], _stacked_P(projs), levels,
                         MAEchoConfig(), "oi", "AUTO", None)


def test_dryrun_backend_rejected():
    """`dryrun_agg.run` (the programmatic entry under the CLI) rejects
    unknown backends up front instead of falling through to auto, and
    the argparse layer lists the valid choices."""
    env_before = os.environ.get("XLA_FLAGS")
    try:
        # the module import sets XLA_FLAGS for subprocess use; jax is
        # already initialized in-process, so restore it afterwards
        from repro.launch import dryrun_agg
        with pytest.raises(ValueError, match="valid choices"):
            dryrun_agg.run("llama3_8b", 2, False, backend="warp")
        argv = sys.argv
        try:
            sys.argv = ["dryrun_agg", "--backend", "warp"]
            with pytest.raises(SystemExit) as e:
                dryrun_agg.main()
            assert e.value.code == 2       # argparse usage error
        finally:
            sys.argv = argv
    finally:
        if env_before is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = env_before


# --------------------------------------------------------------------------
# anti-drift regression: the summary IS what executes
# --------------------------------------------------------------------------
_TRACE_BUST = [1000]


class _GramTap:
    """Wrap every gram entry point (plain setattr, restored in
    close()) so executing an aggregation leaves the per-leaf route
    trail it ACTUALLY took (at trace time).  Not a pytest fixture —
    @given re-runs the test body per example, and real-hypothesis
    forbids function-scoped fixtures inside property tests."""

    _NAMES = {"_leaf_gram_oracle": "oracle",
              "_leaf_gram_kernel": "kernel",
              "_leaf_gram_sharded": "sharded",
              "_leaf_gram_sharded2d": "sharded2d"}

    def __init__(self):
        import repro.core.maecho as M

        self.mod = M
        self.record = []
        self.saved = {}

        def wrap(tag, fn):
            def inner(*a, **k):
                self.record.append(tag)
                return fn(*a, **k)
            return inner

        for name, tag in self._NAMES.items():
            self.saved[name] = getattr(M, name)
            setattr(M, name, wrap(tag, self.saved[name]))
        orig_stacked = M._leaf_gram_stacked
        self.saved["_leaf_gram_stacked"] = orig_stacked

        def stacked(W, V, P, cfg, convention, route, *args, **kw):
            self.record.append(route)
            return orig_stacked(W, V, P, cfg, convention, route,
                                *args, **kw)

        M._leaf_gram_stacked = stacked

    def close(self):
        for name, fn in self.saved.items():
            setattr(self.mod, name, fn)


@given(strat.seeds(), strat.n_clients(), strat.kinds(),
       strat.conventions(), strat.leads(), strat.shapes(),
       strat.bools())
@settings(max_examples=8, deadline=None)
def test_summary_matches_execution(seed, n, kind, convention, lead,
                                   shape, batched):
    """THE drift regression (satellite 1): across the property-harness
    strategy space and every backend, the per-leaf route
    dispatch_summary reports is byte-identical to the route the
    executor's gram phase actually takes."""
    clients, projs, levels, _ = strat.build_case(
        seed, n, kind, convention, lead, shape, False)
    backends = [("kernel", None), ("auto", None),
                ("sharded", _mesh1d()), ("sharded2d", _mesh2d())]
    backend, mesh = backends[seed % len(backends)]
    _TRACE_BUST[0] += 1
    # unique qp_iters busts the executor's jit cache so the trace
    # (where dispatch happens) reruns for this exact case
    cfg = MAEchoConfig(tau=1, eta=0.5, qp_iters=_TRACE_BUST[0],
                       qp_batched=batched)
    tap = _GramTap()
    try:
        maecho_aggregate(clients, projs, cfg, convention=convention,
                         stack_levels=levels, backend=backend,
                         mesh=mesh)
    finally:
        tap.close()
    per_leaf, _ = dispatch_summary(
        clients[0], _stacked_P(projs), levels, cfg, convention,
        backend, mesh)
    assert tap.record == [r for _, _, r in per_leaf], (
        backend, tap.record, per_leaf)


def test_executor_handles_levels2_oracle_leaf_directly():
    """Regression: direct _maecho_jit callers (the dryrun driver) hand
    levels >= 2 leaves straight to the executor WITHOUT
    maecho_aggregate's multi-level flattening — the oracle route must
    collapse the leading stack axes itself (MoE expert / hybrid mamba
    layouts) instead of vmapping a still-stacked leaf."""
    from repro.core.maecho import _maecho_jit

    n, lead, out_d, in_d = 3, (2, 2), 24, 8   # sub-tile -> oracle
    clients, projs = [], []
    for i in range(n):
        k = jax.random.fold_in(jax.random.PRNGKey(5), i)
        clients.append({"W": jax.random.normal(
            k, lead + (out_d, in_d)) * 0.3})
        projs.append({"W": jnp.ones(lead)})   # stacked scalar rule
    cfg = MAEchoConfig(tau=2, eta=0.5, qp_iters=40)
    W0 = jax.tree_util.tree_map(
        lambda *xs: sum(xs) / len(xs), *clients)
    V0 = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *clients)
    P = _stacked_P(projs)
    plan = compile_plan(W0, P, {"W": 2}, cfg, "oi", "oracle", None)
    assert plan.leaves[0].route == "oracle"
    W, _ = _maecho_jit(W0, V0, P, cfg, "oi", plan, None)
    # parity with the public path (which pre-flattens multi stacks)
    want = maecho_aggregate(clients, projs, cfg,
                            stack_levels={"W": 2}, backend="oracle")
    np.testing.assert_allclose(np.asarray(W["W"]),
                               np.asarray(want["W"]), atol=1e-5)


# --------------------------------------------------------------------------
# sharded2d: eligibility gating + fallback chain
# --------------------------------------------------------------------------
def test_sharded_ok2d_divisibility():
    # 1024 = 8 out-tiles, 512 = 4 in-tiles
    assert sharded_ok2d(1024, 512, 8, 4)
    assert sharded_ok2d(1024, 512, 2, 2)
    assert sharded_ok2d(1024, 512, 8, 1)     # degenerate 1-D
    assert not sharded_ok2d(1024, 512, 3, 4)  # out tiles % 3
    assert not sharded_ok2d(1024, 512, 8, 3)  # in tiles % 3
    # below one tile on either dim: never sharded
    assert not sharded_ok2d(64, 512, 1, 1)
    assert not sharded_ok2d(1024, 64, 1, 1)
    # the fleet-spanning case: out too small for 1-D over 8 devices
    # but fine as 2 x 4
    assert not ops.sharded_ok(256, 512, 8)
    assert sharded_ok2d(256, 512, 2, 4)


def test_sharded2d_route_fallback_chain():
    """sharded2d -> sharded -> kernel -> oracle, each gate static."""
    cfg = MAEchoConfig()
    mesh = FakeMesh({"data": 2, "model": 4})
    P = jnp.zeros((3, 512, 512))

    def route(w_shape, m=mesh, c=cfg, P=P):
        return leaf_route(jnp.zeros(w_shape), P, 0, c, "oi",
                          "sharded2d", m)

    assert route((256, 512)) == "sharded2d"   # 2x4 spans 8 devices
    # in-tiles don't divide the model axis: 1-D out-row fallback
    assert route((256, 384)) == "sharded"
    # neither axis divides (320 -> 3 out-tiles): single-device kernel
    assert route((320, 384)) == "kernel"
    # sub-tile: oracle
    assert route((64, 64)) == "oracle"
    # mesh without the in-axis: 1-D fallback
    assert route((256, 512),
                 m=FakeMesh({"data": 2})) == "sharded"
    # stacked leaves ride the same gates
    assert leaf_route(jnp.zeros((4, 256, 512)),
                      jnp.zeros((3, 4, 512, 512)), 1, cfg, "oi",
                      "sharded2d", mesh) == "sharded2d"


def test_sharded2d_missing_in_axis_warns_once():
    """A forced-2-D request on a mesh without the in-axis is still a
    degradation — it must surface via fallback_warn like every other
    rung of the chain, not silently run 1-D."""
    import warnings

    cfg = MAEchoConfig()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        r = leaf_route(jnp.zeros((768, 512)),
                       jnp.zeros((3, 512, 512)), 0, cfg, "oi",
                       "sharded2d", FakeMesh({"data": 2}))
    assert r == "sharded"
    assert any("lacks the in-axis" in str(w.message) for w in rec)


def test_agg_partition_specs_2d():
    """The rules' 2-D aggregation placement specs: rows over the data
    axes AND columns over "model", dense projectors sharded on their
    output column axis only — congruent with the shard_map layout
    ops.maecho_sharded2d_gram builds inline."""
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.sharding.rules import make_rules

    rules = make_rules(FakeMesh({"pod": 2, "data": 16, "model": 16}),
                       get_config("llama3_8b"))
    assert rules.agg_in_axes(4096) == "model"
    assert rules.agg_in_axes(100) is None
    assert rules.agg_weight_spec2d((4096, 2048)) == P(
        ("pod", "data"), "model")
    assert rules.agg_weight_spec2d((4096, 100)) == P(
        ("pod", "data"), None)
    assert rules.agg_weight_spec2d((4096,)) == P(None)
    assert rules.agg_anchor_spec2d((8, 4096, 2048)) == P(
        None, ("pod", "data"), "model")
    assert rules.agg_proj_spec2d((8, 2048, 2048)) == P(
        None, None, "model")


# --------------------------------------------------------------------------
# sharded2d contracts: ONE two-axis psum + L-independent launch count
# --------------------------------------------------------------------------
def test_exactly_one_two_axis_psum_per_leaf_per_iteration():
    """The acceptance contract: a sharded2d leaf costs exactly ONE
    psum per outer iteration, taken over BOTH mesh axis groups at
    once — and the apply phase is collective-free."""
    mesh = _mesh2d()
    tau = 2
    clients, projs, levels = _model(out_d=256, in_d=256, kind="full")
    cfg = MAEchoConfig(tau=tau, eta=0.5, qp_iters=40)
    txt = str(jax.make_jaxpr(
        lambda: maecho_aggregate(clients, projs, cfg,
                                 stack_levels=levels,
                                 backend="sharded2d", mesh=mesh))())
    assert txt.count("psum") == tau, (
        f"expected {tau} psums (one per outer iteration), "
        f"found {txt.count('psum')}")
    assert txt.count("axes=('data', 'model')") == tau, (
        "every sharded2d psum must cover both axis groups in one "
        "collective")


@pytest.mark.parametrize("L", [2, 4])
def test_sharded2d_stacked_one_psum_and_three_launches(L):
    """A stacked sharded2d leaf: one (L, N, N) two-axis psum per outer
    iteration and exactly 3 Pallas launches per iteration (gram,
    Eq. 7, Eq. 11) independent of L — the layer axis rides the grid
    inside each 2-D shard."""
    mesh = _mesh2d()
    tau = 2
    clients, projs, levels = _model(out_d=256, in_d=256, kind="full",
                                    lead=(L,))
    cfg = MAEchoConfig(tau=tau, eta=0.5, qp_iters=40)
    txt = str(jax.make_jaxpr(
        lambda: maecho_aggregate(clients, projs, cfg,
                                 stack_levels=levels,
                                 backend="sharded2d", mesh=mesh))())
    assert txt.count("axes=('data', 'model')") == tau
    assert txt.count("pallas_call") == 3, txt.count("pallas_call")


# --------------------------------------------------------------------------
# sharded2d parity (single device; the 8-device run rides the CI
# smoke `dryrun_agg --sharded-smoke`, which executes the 2-D stage)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["scalar", "diag", "full", "factored"])
def test_sharded2d_gram_apply_parity_one_device(kind):
    N, out_d, in_d = 3, 256, 140          # odd in-dim: padding path
    mesh = _mesh2d()
    k = jax.random.PRNGKey(out_d + in_d)
    W = jax.random.normal(k, (out_d, in_d)) * 0.3
    V = jax.random.normal(jax.random.fold_in(k, 1),
                          (N, out_d, in_d)) * 0.3
    Ps = [strat.make_projector(jax.random.fold_in(k, 10 + i), kind,
                               (), in_d) for i in range(N)]
    P = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *Ps)
    alpha = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 9),
                                             (N,)))

    def step(W, V, P):
        G, ctx = ops.maecho_sharded2d_gram(W, V, P, mesh=mesh,
                                           axis_out="data",
                                           axis_in="model")
        Wn, Vn = ops.maecho_sharded2d_apply(
            alpha, ctx, mesh=mesh, axis_out="data", axis_in="model",
            eta=0.7, frac=0.5, norm=True)
        return G, Wn, Vn

    G, Wn, Vn = jax.jit(step)(W, V, P)
    Gr = ref.maecho_gram_ref(W, V, P)
    Wr = ref.maecho_update_ref_any(W, V, P, alpha, 0.7)
    Vr = ref.maecho_v_update_ref(Wr, V, P, 0.5, True)
    np.testing.assert_allclose(np.asarray(G), np.asarray(Gr),
                               atol=1e-2, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(Wn), np.asarray(Wr),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(Vn), np.asarray(Vr),
                               atol=1e-4)


def test_sharded2d_backend_aggregate_parity_sequential_qp():
    """The qp_batched=False path routes through the same plan."""
    clients, projs, levels = _model(kind="factored")
    cfg = MAEchoConfig(tau=2, eta=0.5, qp_iters=60, qp_batched=False)
    a = maecho_aggregate(clients, projs, cfg, stack_levels=levels,
                         backend="oracle")
    b = maecho_aggregate(clients, projs, cfg, stack_levels=levels,
                         backend="sharded2d", mesh=_mesh2d())
    np.testing.assert_allclose(np.asarray(a["W"]), np.asarray(b["W"]),
                               atol=1e-3)


# --------------------------------------------------------------------------
# true multi-device 2-D runs (fresh process: XLA flag precedes jax)
# --------------------------------------------------------------------------
@pytest.mark.slow
def test_sharded2d_parity_8dev_two_by_four():
    """Acceptance: a (2, 4) factored fleet aggregates a leaf whose
    out-dim cannot span 8 devices 1-D, to <1e-3 of the oracle, with
    exactly one two-axis psum per leaf per outer iteration — the
    subprocess half of the CI smoke, at pytest granularity."""
    import pathlib
    import subprocess
    import textwrap

    repo = pathlib.Path(__file__).resolve().parent.parent
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.dryrun_agg import run_sharded2d_smoke
        assert len(jax.devices()) == 8, jax.devices()
        err, counts, cov_ok = run_sharded2d_smoke(8, tau=2)
        assert err < 1e-3, err
        assert cov_ok and counts.get("sharded2d", 0) >= 3, counts
        print("SHARDED2D_OK", err)
    """)
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(repo / "src") + os.pathsep
           + os.environ.get("PYTHONPATH", "")}
    r = subprocess.run([sys.executable, "-c", code], cwd=repo, env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SHARDED2D_OK" in r.stdout
