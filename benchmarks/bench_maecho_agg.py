"""End-to-end MA-Echo aggregation benchmark (ISSUE 1 tentpole).

Times full ``maecho_aggregate`` runs — Gram, QP, Eq. 7 and Eq. 11 per
outer iteration — comparing the dense-projector jnp oracle against the
factored-projector fast path at several layer sizes and ranks.  On
this CPU-only container the oracle-vs-oracle wall clock is the
meaningful hardware signal (interpret-mode Pallas timing is
simulation); the fused kernel pipeline is additionally verified
allclose against the oracle in interpret mode on a small config.

Rows land in ``BENCH_maecho_agg.json`` via ``benchmarks.run`` — the
perf trajectory future PRs compare against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core.maecho import MAEchoConfig, maecho_aggregate


def _make_problem(out_d: int, in_d: int, rank: int, n_clients: int):
    """Clients plus matching dense / factored projectors describing the
    SAME operator P = U·diag(s)·Uᵀ (so the two paths solve one
    problem and their outputs can be cross-checked)."""
    k0 = jax.random.PRNGKey(out_d + in_d + rank)
    clients, dense, fact = [], [], []
    for i in range(n_clients):
        k = jax.random.fold_in(k0, i)
        W = jax.random.normal(k, (out_d, in_d)) * 0.3
        b = jax.random.normal(jax.random.fold_in(k, 1), (out_d,)) * 0.1
        U = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(k, 2),
                                            (in_d, rank)))[0]
        s = jax.random.uniform(jax.random.fold_in(k, 3), (rank,))
        clients.append({"W": W, "b": b})
        dense.append({"W": (U * s) @ U.T, "b": jnp.ones(())})
        fact.append({"W": {"U": U, "s": s}, "b": jnp.ones(())})
    return clients, dense, fact


def _time_agg(clients, projs, cfg, backend, reps: int = 3):
    fn = lambda: maecho_aggregate(clients, projs, cfg, backend=backend)  # noqa: E731
    fn()                                    # compile
    out, us = timed(fn)
    for _ in range(reps - 1):               # best-of-reps: shed noise
        _, u = timed(fn)
        us = min(us, u)
    return out, us


def run(quick: bool = False):
    N = 5
    cfg = MAEchoConfig(tau=5 if quick else 10, eta=0.5, qp_iters=100)
    sizes = [(512, 512, 64), (512, 512, 128)]
    if not quick:
        sizes += [(1024, 1024, 128), (1024, 1024, 256)]
    for out_d, in_d, rank in sizes:
        clients, dense, fact = _make_problem(out_d, in_d, rank, N)
        wd, us_dense = _time_agg(clients, dense, cfg, "oracle")
        wf, us_fact = _time_agg(clients, fact, cfg, "oracle")
        agree = np.allclose(np.asarray(wd["W"]), np.asarray(wf["W"]),
                            atol=1e-3)
        tag = f"{out_d}x{in_d}_k{rank}_N{N}"
        row(f"maecho_agg/dense_oracle_{tag}", us_dense, "")
        row(f"maecho_agg/factored_oracle_{tag}", us_fact,
            f"speedup={us_dense / max(us_fact, 1):.2f}x;match={agree}")

    # fused kernel pipeline: allclose vs oracle (interpret mode) on a
    # small config — correctness signal, not wall clock
    clients, dense, fact = _make_problem(256, 256, 32, 3)
    vcfg = MAEchoConfig(tau=2, eta=0.5, qp_iters=60)
    w_oracle, _ = _time_agg(clients, dense, vcfg, "oracle")
    w_kernel, us_k = _time_agg(clients, dense, vcfg, "kernel")
    ok_dense = np.allclose(np.asarray(w_oracle["W"]),
                           np.asarray(w_kernel["W"]), atol=1e-3)
    row("maecho_agg/kernel_interpret_dense_256", us_k,
        f"allclose={ok_dense}")
    w_oracle, _ = _time_agg(clients, fact, vcfg, "oracle")
    w_kernel, us_k = _time_agg(clients, fact, vcfg, "kernel")
    ok_fact = np.allclose(np.asarray(w_oracle["W"]),
                          np.asarray(w_kernel["W"]), atol=1e-3)
    row("maecho_agg/kernel_interpret_factored_256", us_k,
        f"allclose={ok_fact}")


if __name__ == "__main__":
    run()
