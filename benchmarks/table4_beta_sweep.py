"""Paper Table 4: varying non-identicalness β, two-model aggregation,
same vs different initialisation (MLP; CNN covered reduced)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import (BENCH_DATA, MLP, ensemble_acc, row,
                               timed, train_locals)
from repro.core.maecho import MAEchoConfig
from repro.data.synthetic import DatasetSpec, generate
from repro.fl import models as pm
from repro.fl.client import evaluate_classifier
from repro.fl.server import one_shot_aggregate


def run(quick: bool = False):
    data = generate(BENCH_DATA)
    betas = [0.01, 20.0] if quick else [0.01, 0.5, 1.5, 20.0]
    for same_init in (False, True):
        tag = "same" if same_init else "diff"
        for beta in betas:
            parts, clients, projs, local = train_locals(
                MLP, data, 2, beta, same_init=same_init,
                epochs=4 if quick else 6)
            for method in ("fedavg", "ot", "maecho", "maecho+ot"):
                kw = {"cfg": MAEchoConfig(tau=30, eta=0.5, mu=20.0)} \
                    if method.startswith("maecho") else {}
                g, us = timed(one_shot_aggregate, MLP, clients, projs,
                              method, **kw)
                acc = evaluate_classifier(MLP, g, data["test_x"],
                                          data["test_y"])
                row(f"table4/mlp-{tag}/beta{beta}/{method}", us,
                    f"acc={acc:.4f}")
            row(f"table4/mlp-{tag}/beta{beta}/ensemble", 0,
                f"acc={ensemble_acc(MLP, clients, data):.4f}")

    if quick:
        return
    # CNN (reduced channels; Norm(.) on, as in the paper's Fig. 3c-d)
    cnn = dataclasses.replace(pm.CNN_SPEC, conv_channels=(16, 16, 16),
                              fc_hidden=(64, 32))
    cdata = generate(DatasetSpec("bench-cnn", n_train=4000, n_test=800,
                                 latent=24, out_dim=3072, seed=1))
    cdata = {k: (v.reshape(-1, 32, 32, 3) if v.ndim == 2 and
                 v.shape[-1] == 3072 else v) for k, v in cdata.items()}
    for beta in (0.01, 0.5):
        parts, clients, projs, local = train_locals(
            cnn, cdata, 2, beta, epochs=3, max_samples=512)
        for method in ("fedavg", "maecho"):
            kw = {"cfg": MAEchoConfig(tau=20, eta=0.5, mu=20.0, norm=True)} \
                if method == "maecho" else {}
            g, us = timed(one_shot_aggregate, cnn, clients, projs,
                          method, **kw)
            acc = evaluate_classifier(cnn, g, cdata["test_x"],
                                      cdata["test_y"])
            row(f"table4/cnn-diff/beta{beta}/{method}", us,
                f"acc={acc:.4f}")


if __name__ == "__main__":
    run()
