"""Paper Figure 8: penalty coefficient μ — global accuracy vs local
anchor decay.  Small μ relaxes the projection constraint (better global
model, slight local loss); large μ pins anchors to their feature span."""
from __future__ import annotations

from benchmarks.common import BENCH_DATA, MLP, row, train_locals
from repro.core.maecho import MAEchoConfig
from repro.data.synthetic import generate
from repro.fl.client import evaluate_classifier
from repro.fl.server import one_shot_aggregate


def run(quick: bool = False):
    data = generate(BENCH_DATA)
    parts, clients, projs, local = train_locals(
        MLP, data, 3, 0.01, epochs=4 if quick else 6)
    mus = [1.0, 200.0] if quick else [0.5, 1.0, 10.0, 200.0]
    for mu in mus:
        g = one_shot_aggregate(MLP, clients, projs, "maecho",
                               cfg=MAEchoConfig(tau=30, eta=0.5, mu=mu))
        acc = evaluate_classifier(MLP, g, data["test_x"],
                                  data["test_y"])
        # local retention: accuracy of the global model on each
        # client's own training data (Fig. 8 b/c analogue)
        rets = [evaluate_classifier(MLP, g, data["train_x"][ix][:800],
                                    data["train_y"][ix][:800])
                for ix in parts]
        row(f"fig8/mu{mu}", 0,
            f"acc={acc:.4f};retention={min(rets):.4f}")


if __name__ == "__main__":
    run()
