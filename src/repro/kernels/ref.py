"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.projections import block_update as _block_update
from repro.models.layers import chunked_attention as _chunked_attention


def maecho_update_ref(W, V, P, alpha, eta: float = 1.0):
    """W' = W + η·(−Σᵢ 2αᵢ (W − Vᵢ) Pᵢ) — Eq. 7, direct einsum form."""
    R = jnp.einsum("noi,nij->noj", W[None] - V, P)
    D = -2.0 * jnp.einsum("n,noi->oi", alpha, R)
    return W + eta * D


def rank_downdate_ref(Q, U, A):
    return Q - U @ A @ U.T


def block_rls_update_ref(Q, Xb, alpha: float = 1.0):
    return _block_update(Q, Xb, alpha)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    return _chunked_attention(q, k, v, causal=causal,
                              q_chunk=min(128, q.shape[1]),
                              k_chunk=min(128, k.shape[1]))
