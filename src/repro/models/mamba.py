"""Mamba selective-state-space blocks.

Mamba1 (falcon-mamba-7b, arXiv:2410.05355) and Mamba2/SSD (zamba2's ssm
blocks, arXiv:2411.15242).  Training/prefill runs a sequential
``lax.scan`` over time carrying only the (B, …, d_state) recurrent state
(the chunked SSD formulation is a recorded §Perf candidate); decode is
the O(1) single-step recurrence — which is why the SSM archs run the
``long_500k`` shape natively.

TPU adaptation (DESIGN.md §6): the depthwise causal conv is expressed as
a sum of ``d_conv`` shifted scaled copies (no im2col), and the per-step
state update is a pure VPU elementwise op batched over (B, d_inner).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def mamba1_layer_init(rng, cfg: ModelConfig, n_layers: int):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    r = s.dt_rank_(d)
    ks = jax.random.split(rng, 6)

    def stk(k, a, b):
        kk = jax.random.split(k, n_layers)
        return jnp.stack([L.dense_init(q, a, b, cfg.pdtype) for q in kk])

    A = jnp.broadcast_to(jnp.arange(1, s.d_state + 1, dtype=jnp.float32),
                         (n_layers, di, s.d_state))
    return {
        "norm": jnp.ones((n_layers, d), cfg.pdtype),
        "in_proj": stk(ks[0], d, 2 * di),
        "conv_w": (jax.random.normal(ks[1], (n_layers, di, s.d_conv)) * 0.1
                   ).astype(cfg.pdtype),
        "conv_b": jnp.zeros((n_layers, di), cfg.pdtype),
        "x_proj": stk(ks[2], di, r + 2 * s.d_state),
        "dt_proj": stk(ks[3], r, di),
        "dt_bias": jnp.zeros((n_layers, di), cfg.pdtype),
        "A_log": jnp.log(A).astype(cfg.pdtype),
        "D": jnp.ones((n_layers, di), cfg.pdtype),
        "out_proj": stk(ks[4], di, d),
    }


def mamba2_layer_init(rng, cfg: ModelConfig, n_layers: int):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = di // s.head_dim
    ks = jax.random.split(rng, 4)

    def stk(k, a, b):
        kk = jax.random.split(k, n_layers)
        return jnp.stack([L.dense_init(q, a, b, cfg.pdtype) for q in kk])

    return {
        "norm": jnp.ones((n_layers, d), cfg.pdtype),
        # fused projection: [x (di), z (di), B (nh*ds? no: ds), C (ds), dt (nh)]
        "in_proj": stk(ks[0], d, 2 * di + 2 * s.d_state + nh),
        "conv_w": (jax.random.normal(ks[1], (n_layers, di + 2 * s.d_state,
                                             s.d_conv)) * 0.1).astype(cfg.pdtype),
        "conv_b": jnp.zeros((n_layers, di + 2 * s.d_state), cfg.pdtype),
        "A_log": jnp.zeros((n_layers, nh), cfg.pdtype),
        "dt_bias": jnp.zeros((n_layers, nh), cfg.pdtype),
        "D": jnp.ones((n_layers, nh), cfg.pdtype),
        "gate_norm": jnp.ones((n_layers, di), cfg.pdtype),
        "out_proj": stk(ks[2], di, d),
    }


def init_params(cfg: ModelConfig, rng):
    ks = jax.random.split(rng, 3)
    params = {
        "embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.pdtype),
        "layers": mamba1_layer_init(ks[1], cfg, cfg.n_layers),
        "ln_f": jnp.ones((cfg.d_model,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[2], cfg.d_model, cfg.vocab,
                                         cfg.pdtype)
    return params


# --------------------------------------------------------------------------
# causal depthwise conv (sum-of-shifts form)
# --------------------------------------------------------------------------
def causal_conv(x, w, b):
    """x: (B, S, C); w: (C, K); b: (C,).  Causal depthwise conv."""
    K = w.shape[-1]
    out = x * w[:, -1]
    for j in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :-j]
        out = out + shifted * w[:, K - 1 - j]
    return out + b


def causal_conv_step(x_t, conv_state, w, b):
    """x_t: (B, C); conv_state: (B, K-1, C) past inputs (oldest first)."""
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,ck->bc", window, w) + b
    return y, window[:, 1:]



def _assoc_scan(dA, dBx):
    """h_t = dA_t * h_{t-1} + dBx_t via associative scan over axis 1.

    Loop-free HLO (log-depth): used by the roofline probe lowerings
    (cfg.ssm_assoc) so XLA cost_analysis sees the true per-token work;
    also the chunk-parallel execution candidate recorded in §Perf.
    """
    def combine(a, b):
        A1, B1 = a
        A2, B2 = b
        return A1 * A2, B1 * A2 + B2

    _, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    return hs


# --------------------------------------------------------------------------
# mamba1 block
# --------------------------------------------------------------------------
def mamba1_block(lp, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d)."""
    s = cfg.ssm
    dt_ = cfg.cdtype
    B_, S, d = x.shape
    di = s.d_inner(d)
    r = s.dt_rank_(d)

    xz = x @ lp["in_proj"].astype(dt_)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = causal_conv(xs, lp["conv_w"].astype(dt_), lp["conv_b"].astype(dt_))
    xs = jax.nn.silu(xs)

    proj = xs @ lp["x_proj"].astype(dt_)                     # (B,S,r+2ds)
    dt_raw, Bc, Cc = jnp.split(proj, [r, r + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt_raw @ lp["dt_proj"].astype(dt_)
                         + lp["dt_bias"].astype(dt_))        # (B,S,di)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))            # (di, ds)

    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)      # (B,S,di,ds)
    dBx = (dt * xs).astype(jnp.float32)[..., None] * \
        Bc.astype(jnp.float32)[..., None, :]                 # (B,S,di,ds)

    if cfg.ssm_assoc:
        hs = _assoc_scan(dA, dBx)                            # (B,S,di,ds)
        y = jnp.einsum("btds,bts->btd",
                       hs, Cc.astype(jnp.float32)).astype(dt_)
    else:
        def step(h, inputs):
            dA_t, dBx_t, C_t = inputs
            h = dA_t * h + dBx_t                             # (B,di,ds)
            y = jnp.einsum("bds,bs->bd", h, C_t)
            return h, y

        h0 = jnp.zeros((B_, di, s.d_state), jnp.float32)
        _, ys = jax.lax.scan(
            step, h0,
            (dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3),
             Cc.astype(jnp.float32).transpose(1, 0, 2)))
        y = ys.transpose(1, 0, 2).astype(dt_)                # (B,S,di)
    y = y + xs * lp["D"].astype(dt_)
    y = y * jax.nn.silu(z)
    return y @ lp["out_proj"].astype(dt_)


def mamba1_decode(lp, x, state, cfg: ModelConfig):
    """x: (B, 1, d); state: {"h": (B,di,ds), "conv": (B,K-1,di)}."""
    s = cfg.ssm
    dt_ = cfg.cdtype
    B_ = x.shape[0]
    d = x.shape[-1]
    r = s.dt_rank_(d)

    xz = x[:, 0] @ lp["in_proj"].astype(dt_)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv = causal_conv_step(xs, state["conv"],
                                lp["conv_w"].astype(dt_),
                                lp["conv_b"].astype(dt_))
    xs = jax.nn.silu(xs)
    proj = xs @ lp["x_proj"].astype(dt_)
    dt_raw, Bc, Cc = jnp.split(proj, [r, r + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt_raw @ lp["dt_proj"].astype(dt_)
                         + lp["dt_bias"].astype(dt_))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)      # (B,di,ds)
    dBx = (dt * xs).astype(jnp.float32)[..., None] * \
        Bc.astype(jnp.float32)[..., None, :]
    h = dA * state["h"] + dBx
    y = jnp.einsum("bds,bs->bd", h, Cc.astype(jnp.float32)).astype(dt_)
    y = y + xs * lp["D"].astype(dt_)
    y = y * jax.nn.silu(z)
    y = (y @ lp["out_proj"].astype(dt_))[:, None]
    return y, {"h": h, "conv": conv}


# --------------------------------------------------------------------------
# mamba2 (SSD, scalar per-head decay) block
# --------------------------------------------------------------------------
def _mamba2_split(lp, x, cfg: ModelConfig):
    s = cfg.ssm
    dt_ = cfg.cdtype
    d = x.shape[-1]
    di = s.d_inner(d)
    nh = di // s.head_dim
    proj = x @ lp["in_proj"].astype(dt_)
    xs = proj[..., :di]
    z = proj[..., di:2 * di]
    Bc = proj[..., 2 * di:2 * di + s.d_state]
    Cc = proj[..., 2 * di + s.d_state:2 * di + 2 * s.d_state]
    dt_raw = proj[..., 2 * di + 2 * s.d_state:]
    return xs, z, Bc, Cc, dt_raw, di, nh


def mamba2_block(lp, x, cfg: ModelConfig):
    s = cfg.ssm
    dt_ = cfg.cdtype
    B_, S, d = x.shape
    xs, z, Bc, Cc, dt_raw, di, nh = _mamba2_split(lp, x, cfg)
    hd = s.head_dim

    xbc = jnp.concatenate([xs, Bc, Cc], axis=-1)
    xbc = causal_conv(xbc, lp["conv_w"].astype(dt_), lp["conv_b"].astype(dt_))
    xbc = jax.nn.silu(xbc)
    xs, Bc, Cc = xbc[..., :di], xbc[..., di:di + s.d_state], \
        xbc[..., di + s.d_state:]

    dt = jax.nn.softplus(dt_raw + lp["dt_bias"].astype(dt_))  # (B,S,nh)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))             # (nh,)
    dA = jnp.exp(dt.astype(jnp.float32) * A)                  # (B,S,nh)
    xh = xs.reshape(B_, S, nh, hd).astype(jnp.float32)
    dBx = dt.astype(jnp.float32)[..., None, None] * \
        Bc.astype(jnp.float32)[:, :, None, :, None] * \
        xh[..., None, :]                                      # (B,S,nh,ds,hd)

    def step(h, inputs):
        dA_t, dBx_t, C_t = inputs                             # (B,nh),(B,nh,ds,hd),(B,ds)
        h = dA_t[..., None, None] * h + dBx_t
        y = jnp.einsum("bhsd,bs->bhd", h, C_t)                # s == d_state
        return h, y

    if cfg.ssm_assoc:
        dA_b = jnp.broadcast_to(dA[..., None, None], dBx.shape)
        hs = _assoc_scan(dA_b, dBx)                    # (B,S,nh,ds,hd)
        y = jnp.einsum("bthsd,bts->bthd", hs,
                       Cc.astype(jnp.float32))
        y = y.reshape(B_, S, di).astype(dt_)
    else:
        h0 = jnp.zeros((B_, nh, s.d_state, hd), jnp.float32)
        _, ys = jax.lax.scan(
            step, h0,
            (dA.transpose(1, 0, 2), dBx.transpose(1, 0, 2, 3, 4),
             Cc.astype(jnp.float32).transpose(1, 0, 2)))
        y = ys.transpose(1, 0, 2, 3).reshape(B_, S, di).astype(dt_)
    y = y + xs * jnp.repeat(lp["D"].astype(dt_), hd)
    y = L.rms_norm(y * jax.nn.silu(z), lp["gate_norm"], cfg.norm_eps)
    return y @ lp["out_proj"].astype(dt_)


def mamba2_decode(lp, x, state, cfg: ModelConfig):
    s = cfg.ssm
    dt_ = cfg.cdtype
    B_ = x.shape[0]
    xs, z, Bc, Cc, dt_raw, di, nh = _mamba2_split(lp, x[:, 0:1], cfg)
    hd = s.head_dim
    xs, z, Bc, Cc, dt_raw = (t[:, 0] for t in (xs, z, Bc, Cc, dt_raw))

    xbc = jnp.concatenate([xs, Bc, Cc], axis=-1)
    xbc, conv = causal_conv_step(xbc, state["conv"],
                                 lp["conv_w"].astype(dt_),
                                 lp["conv_b"].astype(dt_))
    xbc = jax.nn.silu(xbc)
    xs, Bc, Cc = xbc[..., :di], xbc[..., di:di + s.d_state], \
        xbc[..., di + s.d_state:]

    dt = jax.nn.softplus(dt_raw + lp["dt_bias"].astype(dt_))  # (B,nh)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32) * A)                  # (B,nh)
    xh = xs.reshape(B_, nh, hd).astype(jnp.float32)
    dBx = dt.astype(jnp.float32)[..., None, None] * \
        Bc.astype(jnp.float32)[:, None, :, None] * xh[:, :, None, :]
    h = dA[..., None, None] * state["h"] + dBx                # (B,nh,ds,hd)
    y = jnp.einsum("bhsd,bs->bhd", h, Cc.astype(jnp.float32))
    y = y.reshape(B_, di).astype(dt_)
    y = y + xs * jnp.repeat(lp["D"].astype(dt_), hd)
    y = L.rms_norm(y * jax.nn.silu(z), lp["gate_norm"], cfg.norm_eps)
    y = (y @ lp["out_proj"].astype(dt_))[:, None]
    return y, {"h": h, "conv": conv}


# --------------------------------------------------------------------------
# full mamba1 model (falcon-mamba)
# --------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, batch):
    x = params["embed"].astype(cfg.cdtype)[batch["tokens"]]

    def body(x, lp):
        return x + mamba1_block(lp, L.rms_norm(x, lp["norm"], cfg.norm_eps),
                                cfg), None

    body_ = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_, x, params["layers"], unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.cdtype)
    return x @ head


def loss_fn(cfg: ModelConfig, params, batch):
    return L.softmax_xent(forward(cfg, params, batch), batch["labels"],
                          batch.get("loss_mask"))


def prefill(cfg: ModelConfig, params, batch):
    """Forward over the prompt; returns (last_logits, ssm_state_cache).

    The SSM state is O(1) in sequence length — the recurrence's final
    (h, conv-tail) per layer is the whole decode cache.
    """
    x = params["embed"].astype(cfg.cdtype)[batch["tokens"]]
    s = cfg.ssm

    def body(x, lp):
        h_in = L.rms_norm(x, lp["norm"], cfg.norm_eps)
        y, state = _mamba1_block_with_state(lp, h_in, cfg)
        return x + y, state

    body_ = jax.checkpoint(body) if cfg.remat else body
    x, cache = jax.lax.scan(body_, x, params["layers"], unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = L.rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.cdtype)
    return x @ head, cache


def _mamba1_block_with_state(lp, x, cfg: ModelConfig):
    """mamba1_block that also returns the final recurrent state."""
    s = cfg.ssm
    dt_ = cfg.cdtype
    B_, S, d = x.shape
    di = s.d_inner(d)
    r = s.dt_rank_(d)

    xz = x @ lp["in_proj"].astype(dt_)
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_tail = xs[:, -(s.d_conv - 1):, :]              # pre-activation taps
    xs = causal_conv(xs, lp["conv_w"].astype(dt_), lp["conv_b"].astype(dt_))
    xs = jax.nn.silu(xs)

    proj = xs @ lp["x_proj"].astype(dt_)
    dt_raw, Bc, Cc = jnp.split(proj, [r, r + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt_raw @ lp["dt_proj"].astype(dt_)
                         + lp["dt_bias"].astype(dt_))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)
    dBx = (dt * xs).astype(jnp.float32)[..., None] * \
        Bc.astype(jnp.float32)[..., None, :]

    def step(h, inputs):
        dA_t, dBx_t, C_t = inputs
        h = dA_t * h + dBx_t
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    if cfg.ssm_assoc:
        hs = _assoc_scan(dA, dBx)
        h_fin = hs[:, -1]
        y = jnp.einsum("btds,bts->btd",
                       hs, Cc.astype(jnp.float32)).astype(dt_)
    else:
        h0 = jnp.zeros((B_, di, s.d_state), jnp.float32)
        h_fin, ys = jax.lax.scan(
            step, h0,
            (dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3),
             Cc.astype(jnp.float32).transpose(1, 0, 2)))
        y = ys.transpose(1, 0, 2).astype(dt_)
    y = y + xs * lp["D"].astype(dt_)
    y = y * jax.nn.silu(z)
    return y @ lp["out_proj"].astype(dt_), {"h": h_fin, "conv": conv_tail}


def init_cache(cfg: ModelConfig, batch: int, window: int = 0):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nL = cfg.n_layers
    return {
        "h": jnp.zeros((nL, batch, di, s.d_state), jnp.float32),
        "conv": jnp.zeros((nL, batch, s.d_conv - 1, di), cfg.cdtype),
    }


def decode_step(cfg: ModelConfig, params, cache, token, position):
    x = params["embed"].astype(cfg.cdtype)[token]

    def body(x, scanned):
        lp, st = scanned
        y, st = mamba1_decode(lp, L.rms_norm(x, lp["norm"], cfg.norm_eps),
                              st, cfg)
        return x + y, st

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache), unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.cdtype)
    return x @ head, new_cache
