"""Null-space projection matrices (paper §4 "Null space projection").

For a layer with input features X ∈ R^{n×d}, the row-space projector is

    P = Xᵀ (X Xᵀ + zI)⁻¹ X ∈ R^{d×d}

ΔW projected by (I − P) leaves the layer's input→output map unchanged
on the training data — the mechanism MA-Echo uses to keep the global
model from forgetting each client.

Computing P via the n×n Gram inverse is infeasible for n ≫ d, so —
exactly as the paper does, citing OWM [40] — we maintain the
*orthogonal* projector Q ≈ (I − P) with a recursive-least-squares
update and recover P = I − Q:

    rank-1 (OWM):   Q ← Q − (Q x)(Q x)ᵀ / (α + xᵀ Q x)
    block  (ours):  Q ← Q − Q X_bᵀ (α I_b + X_b Q X_bᵀ)⁻¹ X_b Q

The block form is the TPU adaptation (DESIGN.md §6): a b×b solve plus
GEMMs instead of n sequential rank-1 vector updates; both are exact
applications of Woodbury and agree to numerical precision.  The Pallas
kernel in ``repro.kernels.projection_update`` implements the block
update with explicit VMEM tiling; ``ref.py`` points back here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def projection_direct(X, z: float = 1e-3):
    """P = Xᵀ(XXᵀ + zI)⁻¹X — only for small n (tests / tiny layers)."""
    n = X.shape[0]
    G = X @ X.T + z * jnp.eye(n, dtype=X.dtype)
    return X.T @ jnp.linalg.solve(G, X)


def null_projector_init(d: int, dtype=jnp.float32):
    """Q₀ = I (empty feature set: every direction is null space)."""
    return jnp.eye(d, dtype=dtype)


def owm_update(Q, x, alpha: float = 1e-3):
    """Rank-1 RLS update with one feature vector x ∈ R^d."""
    qx = Q @ x
    return Q - jnp.outer(qx, qx) / (alpha + x @ qx)


def block_update(Q, Xb, alpha: float = 1e-3):
    """Block-RLS update with a batch X_b ∈ R^{b×d} (Woodbury, exact)."""
    QX = Q @ Xb.T                                  # (d, b)
    S = alpha * jnp.eye(Xb.shape[0], dtype=Q.dtype) + Xb @ QX
    return Q - QX @ jnp.linalg.solve(S, QX.T)


def null_projector_from_features(X, alpha: float = 1e-3,
                                 block: int = 128):
    """Stream X through block-RLS updates.  Returns Q ≈ I − P."""
    n, d = X.shape
    pad = (-n) % block
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    nb = Xp.shape[0] // block
    blocks = Xp.reshape(nb, block, d)
    # padded rows are zero vectors: block_update with zero rows is a no-op
    Q = null_projector_init(d, X.dtype)

    def step(Q, Xb):
        return block_update(Q, Xb, alpha), None

    Q, _ = jax.lax.scan(step, Q, blocks)
    return Q


def null_projector_from_features_continue(Q, X, alpha: float = 1e-3,
                                          block: int = 128):
    """Continue an existing Q with more feature rows (streaming use)."""
    n, d = X.shape
    pad = (-n) % block
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    blocks = Xp.reshape(Xp.shape[0] // block, block, d)

    def step(Q, Xb):
        return block_update(Q, Xb, alpha), None

    Q, _ = jax.lax.scan(step, Q, blocks)
    return Q


def projection_from_features(X, alpha: float = 1e-3, block: int = 128):
    """P (row-space projector) via the streaming block form."""
    d = X.shape[-1]
    return jnp.eye(d, dtype=X.dtype) - null_projector_from_features(
        X, alpha, block)


def symmetrize(P):
    return 0.5 * (P + P.T)


# --------------------------------------------------------------------------
# SVD compression (paper §7.3 "The SVD decomposition for P")
# --------------------------------------------------------------------------
def svd_compress(P, k: int):
    """Keep the top-k eigencomponents of the (symmetric PSD) projector.

    Returns (U_k, s_k) with P ≈ U_k diag(s_k) U_kᵀ.  Communication cost
    drops from d² to k·(d+1) — the paper's Table 6 experiment.
    """
    s, U = jnp.linalg.eigh(symmetrize(P))
    idx = jnp.argsort(s)[::-1][:k]
    return U[:, idx], s[idx]


def svd_restore(U_k, s_k):
    return (U_k * s_k) @ U_k.T


def compression_ratio(d: int, k: int) -> float:
    return (k * (d + 1)) / float(d * d)


def factor_projection(P, k: int) -> dict:
    """Factored form {"U", "s"} with P ≈ U·diag(s)·Uᵀ — accepted
    directly by ``core.maecho`` (the beyond-paper compute optimisation;
    EXPERIMENTS.md §Perf H3)."""
    U, s = svd_compress(P, k)
    return {"U": U, "s": s}


def factor_projection_tree(projs, k: int, min_dim: int = 4):
    """Factor every full (d,d) projector leaf in a projection pytree."""
    import jax

    def walk(node):
        if isinstance(node, dict):
            if set(node) == {"U", "s"}:
                return node
            return {kk: walk(v) for kk, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(walk(v) for v in node)
        if hasattr(node, "ndim") and node.ndim == 2 and \
                node.shape[0] == node.shape[1] and node.shape[0] >= min_dim:
            return factor_projection(node, min(k, node.shape[0]))
        return node

    return walk(projs)
