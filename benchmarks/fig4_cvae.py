"""Paper Figure 4: CVAE decoder aggregation.

Two CVAEs trained on disjoint digit groups ({0,1,3,4,7} / {2,5,6,8,9});
the aggregated decoder must generate ALL classes.  Quantified (no eyes
on this box) as per-class decode error against the class's mean image:
local decoders fail on the classes they never saw; MA-Echo's aggregate
stays close to the GT decoder on every class.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core.maecho import MAEchoConfig
from repro.data.synthetic import DatasetSpec, generate
from repro.fl import models as pm
from repro.fl.server import one_shot_aggregate
from repro.core import projections as proj
from repro.optim import adamw


def _train_cvae(spec, x, y, steps=300, seed=0):
    params = pm.cvae_init(spec, jax.random.PRNGKey(seed))
    opt = adamw(1e-3)
    state = opt.init(params)

    @jax.jit
    def step(p, s, bx, by, rng, t):
        loss, g = jax.value_and_grad(pm.cvae_elbo)(p, bx, by, rng)
        p, s = opt.update(g, s, p, t)
        return p, s, loss

    rng = np.random.RandomState(seed)
    key = jax.random.PRNGKey(seed)
    for t in range(steps):
        ix = rng.randint(0, len(x), size=128)
        key, sub = jax.random.split(key)
        y1 = jax.nn.one_hot(jnp.asarray(y[ix]), spec.n_classes)
        params, state, loss = step(params, state,
                                   jnp.asarray(x[ix]), y1, sub, t)
    return params


def _decoder_projections(spec, dec, n=512, alpha=1.0, seed=0):
    """Features for the decoder layers from its own (z, y) inputs."""
    key = jax.random.PRNGKey(seed)
    z = jax.random.normal(key, (n, spec.latent))
    y = jax.nn.one_hot(jax.random.randint(
        jax.random.fold_in(key, 1), (n,), 0, spec.n_classes),
        spec.n_classes)
    _, feats = pm.cvae_decode(dec, z, y, return_features=True)
    out = []
    for f in feats:
        f = f / jnp.maximum(jnp.linalg.norm(f, axis=-1, keepdims=True),
                            1e-6)
        out.append({"W": proj.projection_from_features(f, alpha),
                    "b": jnp.ones(())})
    return {"dec": out}


def _per_class_error(spec, dec, class_means, n=128, seed=1):
    key = jax.random.PRNGKey(seed)
    errs = []
    for c in range(spec.n_classes):
        z = jax.random.normal(jax.random.fold_in(key, c),
                              (n, spec.latent))
        y = jax.nn.one_hot(jnp.full((n,), c), spec.n_classes)
        imgs = pm.cvae_decode(dec, z, y)
        errs.append(float(jnp.mean(jnp.square(
            jnp.mean(imgs, 0) - class_means[c]))))
    return errs


def run(quick: bool = False):
    spec = dataclasses.replace(pm.CVAE_SPEC, latent=16)
    data = generate(DatasetSpec("cvae", n_train=6000, n_test=1000,
                                latent=16, out_dim=784, seed=5))
    x = (data["train_x"] - data["train_x"].min()) / \
        (data["train_x"].max() - data["train_x"].min())
    y = data["train_y"]
    groups = [np.isin(y, [0, 1, 3, 4, 7]), np.isin(y, [2, 5, 6, 8, 9])]
    class_means = jnp.stack([jnp.asarray(x[y == c].mean(0))
                             for c in range(10)])

    steps = 100 if quick else 400
    models, projs = [], []
    for i, gmask in enumerate(groups):
        p = _train_cvae(spec, x[gmask], y[gmask], steps=steps, seed=i)
        models.append(p)
        projs.append(_decoder_projections(spec, p["dec"], seed=i))
    gt = _train_cvae(spec, x, y, steps=steps, seed=9)

    decs = {f"model{i}": {"dec": m["dec"]} for i, m in
            enumerate(models)}
    decs["average"] = one_shot_aggregate(
        spec, [{"dec": m["dec"]} for m in models], None, "fedavg")
    decs["maecho"] = one_shot_aggregate(
        spec, [{"dec": m["dec"]} for m in models], projs, "maecho",
        cfg=MAEchoConfig(tau=30, eta=0.5, mu=20.0))
    decs["gt"] = {"dec": gt["dec"]}

    for name, d in decs.items():
        errs = _per_class_error(spec, d["dec"], class_means)
        seen = {"model0": [0, 1, 3, 4, 7], "model1": [2, 5, 6, 8, 9]}
        unseen = (sorted(set(range(10)) - set(seen[name]))
                  if name in seen else list(range(10)))
        row(f"fig4/{name}", 0,
            f"err_all={np.mean(errs):.4f};"
            f"err_unseen={np.mean([errs[c] for c in unseen]):.4f}")


if __name__ == "__main__":
    run()
