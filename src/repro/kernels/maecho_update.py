"""Pallas TPU kernel: fused MA-Echo layer update (Eq. 7).

Computes, for one layer,   W' = W + η · D,
         D = − Σ_{i<N} 2 αᵢ (W − Vᵢ) Pᵢ

The PyTorch reference runs N separate GEMMs plus adds, streaming W−Vᵢ
and the (d_in×d_in) projector from HBM each time.  On TPU we tile the
(out×in) output into MXU-aligned VMEM blocks and accumulate the client
sum **in VMEM scratch** across the (client, k-block) grid axes, so each
output tile is written once and the residual (W−Vᵢ) tile is formed
in-register — the fusion the paper's hot loop wants (DESIGN.md §6).

Grid: (n_out, n_in, N, n_k); scratch persists across the two inner
axes.  Block shapes (bo, bk) / (bk, bi) / (bo, bi), 128-aligned.

Fast paths matching ``maecho_gram`` / ``maecho_v_update``:
  - ``maecho_update_factored``: Pᵢ = Uᵢ·diag(sᵢ)·Uᵢᵀ kept factored —
    the per-client GEMM contracts the (N, out, k) compressed residual
    Aᵢ = ((W − Vᵢ)Uᵢ)·diag(sᵢ) against Uᵢᵀ, reduction over the rank k
    instead of in (O(out·in·k) per client);
  - ``maecho_update_diag``: 1-D projectors, single elementwise pass,
    no scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(alpha_ref, w_ref, v_ref, p_ref, wout_ref, out_ref, acc_ref,
            *, eta: float, n_clients: int, n_k: int, off: int = 0):
    i = pl.program_id(off + 2)    # client index
    k = pl.program_id(off + 3)    # reduction block index

    @pl.when((i == 0) & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # stacked grids carry the layer axis in front: α is (L, N) in SMEM
    a_i = alpha_ref[pl.program_id(0), i] if off else alpha_ref[i]
    resid = (w_ref[...] - v_ref[...]).astype(jnp.float32)    # (bo, bk)
    pblk = p_ref[...].astype(jnp.float32)                    # (bk, bi)
    acc_ref[...] += -2.0 * a_i * jax.lax.dot(
        resid, pblk, preferred_element_type=jnp.float32)

    @pl.when((i == n_clients - 1) & (k == n_k - 1))
    def _finalize():
        out_ref[...] = (wout_ref[...].astype(jnp.float32)
                        + eta * acc_ref[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eta", "bo", "bi", "bk",
                                             "interpret"))
def maecho_update(W, V, P, alpha, *, eta: float = 1.0, bo: int = 128,
                  bi: int = 128, bk: int = 128, interpret: bool = True):
    """W: (out, in); V: (N, out, in); P: (N, in, in); alpha: (N,).

    Returns W' = W + η·D with D from Eq. 7.  ``interpret=True`` runs the
    kernel body on CPU (this container); on TPU pass ``False``.
    """
    out_d, in_d = W.shape
    N = V.shape[0]
    bo = min(bo, out_d)
    bi = min(bi, in_d)
    bk = min(bk, in_d)
    assert out_d % bo == 0 and in_d % bi == 0 and in_d % bk == 0, (
        "pad layer dims to block multiples")
    n_out, n_in, n_k = out_d // bo, in_d // bi, in_d // bk

    grid = (n_out, n_in, N, n_k)
    kernel = functools.partial(_kernel, eta=eta, n_clients=N, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                  # alpha
            pl.BlockSpec((bo, bk), lambda o, j, i, k: (o, k)),      # W (resid)
            pl.BlockSpec((None, bo, bk), lambda o, j, i, k: (i, o, k)),  # V
            pl.BlockSpec((None, bk, bi), lambda o, j, i, k: (i, k, j)),  # P
            pl.BlockSpec((bo, bi), lambda o, j, i, k: (o, j)),      # W (out)
        ],
        out_specs=pl.BlockSpec((bo, bi), lambda o, j, i, k: (o, j)),
        out_shape=jax.ShapeDtypeStruct((out_d, in_d), W.dtype),
        scratch_shapes=[pltpu.VMEM((bo, bi), jnp.float32)],
        interpret=interpret,
    )(alpha, W, V, P, W)


def _left_kernel(alpha_ref, a_ref, ut_ref, wout_ref, out_ref, acc_ref,
                 *, eta: float, n_clients: int, n_k: int, off: int = 0):
    """Residual given as a left factor: (W − Vᵢ)Pᵢ = Aᵢ @ Uᵢᵀ."""
    i = pl.program_id(off + 2)
    k = pl.program_id(off + 3)

    @pl.when((i == 0) & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_i = alpha_ref[pl.program_id(0), i] if off else alpha_ref[i]
    acc_ref[...] += -2.0 * a_i * jax.lax.dot(
        a_ref[...].astype(jnp.float32), ut_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when((i == n_clients - 1) & (k == n_k - 1))
    def _finalize():
        out_ref[...] = (wout_ref[...].astype(jnp.float32)
                        + eta * acc_ref[...]).astype(out_ref.dtype)


def maecho_update_factored(W, V, U, s, alpha, *, eta: float = 1.0,
                           bo: int = 128, bi: int = 128, bk: int = 128,
                           interpret: bool = True):
    """Factored Pᵢ = Uᵢ·diag(sᵢ)·Uᵢᵀ.  U: (N, in, k); s: (N, k)."""
    from repro.kernels.maecho_gram import compressed_residual

    A = compressed_residual(W, V, U, s)                  # (N, out, k)
    UT = jnp.swapaxes(U, 1, 2).astype(jnp.float32)       # (N, k, in)
    return maecho_update_left(W, A, UT, alpha, eta=eta, bo=bo, bi=bi,
                              bk=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eta", "bo", "bi", "bk",
                                             "interpret"))
def maecho_update_left(W, A, UT, alpha, *, eta: float = 1.0,
                       bo: int = 128, bi: int = 128, bk: int = 128,
                       interpret: bool = True):
    """Eq. 7 from pre-factored residuals Rᵢ = Aᵢ @ UTᵢ (shareable with
    ``maecho_gram_left`` — one ``compressed_residual`` per iteration)."""
    out_d, in_d = W.shape
    N, _, kd = A.shape
    bo, bi, bk = min(bo, out_d), min(bi, in_d), min(bk, kd)
    assert out_d % bo == 0 and in_d % bi == 0 and kd % bk == 0, (
        "pad layer dims / rank to block multiples")
    n_out, n_in, n_k = out_d // bo, in_d // bi, kd // bk
    kernel = functools.partial(_left_kernel, eta=eta, n_clients=N,
                               n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(n_out, n_in, N, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                   # alpha
            pl.BlockSpec((None, bo, bk), lambda o, j, i, k: (i, o, k)),  # A
            pl.BlockSpec((None, bk, bi), lambda o, j, i, k: (i, k, j)),  # Uᵀ
            pl.BlockSpec((bo, bi), lambda o, j, i, k: (o, j)),       # W (out)
        ],
        out_specs=pl.BlockSpec((bo, bi), lambda o, j, i, k: (o, j)),
        out_shape=jax.ShapeDtypeStruct((out_d, in_d), W.dtype),
        scratch_shapes=[pltpu.VMEM((bo, bi), jnp.float32)],
        interpret=interpret,
    )(alpha, A, UT, W)


# --------------------------------------------------------------------------
# stacked-layer variants: the scan-layer axis L rides the grid outermost,
# α is the per-layer (L, N) stack, one launch covers the whole leaf
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("eta", "bo", "bi", "bk",
                                             "interpret"))
def maecho_update_stacked(W, V, P, alpha, *, eta: float = 1.0,
                          bo: int = 128, bi: int = 128, bk: int = 128,
                          interpret: bool = True):
    """W: (L, out, in); V: (N, L, out, in); P: (N, L, in, in);
    alpha: (L, N).  Returns the (L, out, in) Eq. 7 update from one
    launch — grid (L, n_out, n_in, N, n_k), layer axis outermost."""
    L, out_d, in_d = W.shape
    N = V.shape[0]
    bo, bi, bk = min(bo, out_d), min(bi, in_d), min(bk, in_d)
    assert out_d % bo == 0 and in_d % bi == 0 and in_d % bk == 0, (
        "pad layer dims to block multiples")
    n_out, n_in, n_k = out_d // bo, in_d // bi, in_d // bk
    kernel = functools.partial(_kernel, eta=eta, n_clients=N, n_k=n_k,
                               off=1)
    return pl.pallas_call(
        kernel,
        grid=(L, n_out, n_in, N, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                  # alpha
            pl.BlockSpec((None, bo, bk),
                         lambda l, o, j, i, k: (l, o, k)),          # W (res)
            pl.BlockSpec((None, None, bo, bk),
                         lambda l, o, j, i, k: (i, l, o, k)),       # V
            pl.BlockSpec((None, None, bk, bi),
                         lambda l, o, j, i, k: (i, l, k, j)),       # P
            pl.BlockSpec((None, bo, bi),
                         lambda l, o, j, i, k: (l, o, j)),          # W (out)
        ],
        out_specs=pl.BlockSpec((None, bo, bi),
                               lambda l, o, j, i, k: (l, o, j)),
        out_shape=jax.ShapeDtypeStruct((L, out_d, in_d), W.dtype),
        scratch_shapes=[pltpu.VMEM((bo, bi), jnp.float32)],
        interpret=interpret,
    )(alpha, W, V, P, W)


@functools.partial(jax.jit, static_argnames=("eta", "bo", "bi", "bk",
                                             "interpret"))
def maecho_update_left_stacked(W, A, UT, alpha, *, eta: float = 1.0,
                               bo: int = 128, bi: int = 128,
                               bk: int = 128, interpret: bool = True):
    """Stacked Eq. 7 from pre-factored residuals Rₗᵢ = Aₗᵢ @ UTₗᵢ
    (A shared with ``maecho_gram_left_stacked`` — one
    ``compressed_residual`` per leaf per iteration).
    W: (L, out, in); A: (N, L, out, k); UT: (N, L, k, in);
    alpha: (L, N)."""
    L, out_d, in_d = W.shape
    N, _, _, kd = A.shape
    bo, bi, bk = min(bo, out_d), min(bi, in_d), min(bk, kd)
    assert out_d % bo == 0 and in_d % bi == 0 and kd % bk == 0, (
        "pad layer dims / rank to block multiples")
    n_out, n_in, n_k = out_d // bo, in_d // bi, kd // bk
    kernel = functools.partial(_left_kernel, eta=eta, n_clients=N,
                               n_k=n_k, off=1)
    return pl.pallas_call(
        kernel,
        grid=(L, n_out, n_in, N, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                  # alpha
            pl.BlockSpec((None, None, bo, bk),
                         lambda l, o, j, i, k: (i, l, o, k)),       # A
            pl.BlockSpec((None, None, bk, bi),
                         lambda l, o, j, i, k: (i, l, k, j)),       # Uᵀ
            pl.BlockSpec((None, bo, bi),
                         lambda l, o, j, i, k: (l, o, j)),          # W (out)
        ],
        out_specs=pl.BlockSpec((None, bo, bi),
                               lambda l, o, j, i, k: (l, o, j)),
        out_shape=jax.ShapeDtypeStruct((L, out_d, in_d), W.dtype),
        scratch_shapes=[pltpu.VMEM((bo, bi), jnp.float32)],
        interpret=interpret,
    )(alpha, A, UT, W)


@functools.partial(jax.jit, static_argnames=("eta", "bo", "bi",
                                             "interpret"))
def maecho_update_diag_stacked(W, V, p, alpha, *, eta: float = 1.0,
                               bo: int = 128, bi: int = 128,
                               interpret: bool = True):
    """Stacked diagonal projectors.  W: (L, out, in);
    V: (N, L, out, in); p: (N, L, in); alpha: (L, N)."""
    L, out_d, in_d = W.shape
    N = V.shape[0]
    bo, bi = min(bo, out_d), min(bi, in_d)
    assert out_d % bo == 0 and in_d % bi == 0, (
        "pad layer dims to block multiples")
    p4 = p.reshape(N, L, 1, in_d)
    a4 = alpha.T.reshape(N, L, 1, 1).astype(jnp.float32)
    kernel = functools.partial(_diag_kernel, eta=eta)
    return pl.pallas_call(
        kernel,
        grid=(L, out_d // bo, in_d // bi),
        in_specs=[
            pl.BlockSpec((None, bo, bi), lambda l, o, j: (l, o, j)),   # W
            pl.BlockSpec((N, None, bo, bi),
                         lambda l, o, j: (0, l, o, j)),                # V
            pl.BlockSpec((N, None, 1, bi),
                         lambda l, o, j: (0, l, 0, j)),                # p
            pl.BlockSpec((N, None, 1, 1),
                         lambda l, o, j: (0, l, 0, 0)),                # alpha
        ],
        out_specs=pl.BlockSpec((None, bo, bi), lambda l, o, j: (l, o, j)),
        out_shape=jax.ShapeDtypeStruct((L, out_d, in_d), W.dtype),
        interpret=interpret,
    )(W, V, p4, a4)


def _diag_kernel(w_ref, v_ref, p_ref, alpha_ref, out_ref, *, eta: float):
    w = w_ref[...].astype(jnp.float32)                   # (bo, bi)
    v = v_ref[...].astype(jnp.float32)                   # (N, bo, bi)
    p = p_ref[...].astype(jnp.float32)                   # (N, 1, bi)
    a = alpha_ref[...].astype(jnp.float32)               # (N, 1, 1)
    d = jnp.sum(-2.0 * a * (w[None] - v) * p, axis=0)
    out_ref[...] = (w + eta * d).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eta", "bo", "bi",
                                             "interpret"))
def maecho_update_diag(W, V, p, alpha, *, eta: float = 1.0,
                       bo: int = 128, bi: int = 128,
                       interpret: bool = True):
    """Diagonal projectors.  p: (N, in); alpha: (N,)."""
    out_d, in_d = W.shape
    N = V.shape[0]
    bo, bi = min(bo, out_d), min(bi, in_d)
    assert out_d % bo == 0 and in_d % bi == 0, (
        "pad layer dims to block multiples")
    p3 = p.reshape(N, 1, in_d)
    a3 = alpha.reshape(N, 1, 1).astype(jnp.float32)
    kernel = functools.partial(_diag_kernel, eta=eta)
    return pl.pallas_call(
        kernel,
        grid=(out_d // bo, in_d // bi),
        in_specs=[
            pl.BlockSpec((bo, bi), lambda o, j: (o, j)),            # W
            pl.BlockSpec((N, bo, bi), lambda o, j: (0, o, j)),      # V
            pl.BlockSpec((N, 1, bi), lambda o, j: (0, 0, j)),       # p
            pl.BlockSpec((N, 1, 1), lambda o, j: (0, 0, 0)),        # alpha
        ],
        out_specs=pl.BlockSpec((bo, bi), lambda o, j: (o, j)),
        out_shape=jax.ShapeDtypeStruct((out_d, in_d), W.dtype),
        interpret=interpret,
    )(W, V, p3, a3)
