"""Pallas TPU kernel: single-token decode attention over the KV cache.

One generated token's q attends the (B, W, Hkv, D) sliding-window ring
buffer.  The window is blocked (``bw`` slots per grid step) with online
softmax, and the validity mask rides the grid: a window block holding no
valid slot is skipped entirely (``pl.when``), so a mostly-empty ring
buffer costs only its live blocks — unlike the dense oracle einsum in
``repro.models.layers.decode_attention_oracle``, which recomputes
O(B·W·H·D) every generated token regardless of fill.

GQA folds the query-head group into the q block's row axis: head
h = hkv * group + g matches the oracle's grouped reshape and the
``h // group`` index-map trick in ``flash_attention``.

Two grid layouts share the math:

* ``fold_batch=False`` — grid (B, Hkv, n_w), blocks (group, D) /
  (bw, D).  The TPU shape: VMEM-sized blocks, 2-D MXU dots, one cache
  pass per KV head regardless of the q:kv ratio.
* ``fold_batch=True`` — grid (n_w,), whole-batch blocks with batched
  einsums in the body.  The interpreter shape: interpret mode lowers
  the grid to a ``lax.while_loop`` whose carry holds the *full* input
  arrays and re-writes them every step, so wall-clock is roughly
  grid_steps × operand_bytes — folding (B, Hkv) into the block cuts
  the step count by B·Hkv while XLA fuses the larger per-step compute.

``fold_batch=None`` resolves to the interpret flag.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.env import interpret_default

NEG_INF = -1e30


def _kernel_fine(q_ref, k_ref, v_ref, mask_ref, o_ref, acc_ref, m_ref,
                 l_ref, *, scale: float, n_w: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = mask_ref[...] > 0                        # (bw,)

    # skip window blocks with no valid slot — a ring buffer filled to
    # S of W slots only pays ceil(S / bw) blocks
    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[...].astype(jnp.float32)           # (group, D)
        k = k_ref[...].astype(jnp.float32)           # (bw, D)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot(q, k.T,
                        preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[None, :], s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # explicit zero (not just exp(NEG_INF - m)): with m == NEG_INF
        # (row empty so far) exp(s - m) would be exp(0) = 1 per slot
        p = jnp.exp(s - m_new[:, None]) * valid[None, :]
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot(p, v,
                                      preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == n_w - 1)
    def _finalize():
        # an all-invalid mask leaves l == 0: the clamp returns zeros
        # (finite), where the oracle's softmax-over-NEG_INF degrades to
        # mean(v) — callers never read attention at position < 0, so
        # only the no-NaN contract matters (pinned in tests)
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...][:, None], 1e-30)
                      ).astype(o_ref.dtype)


def _kernel_batched(q_ref, k_ref, v_ref, mask_ref, o_ref, acc_ref, m_ref,
                    l_ref, *, scale: float, n_w: int):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = mask_ref[...] > 0                        # (B, bw)

    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[...].astype(jnp.float32)           # (B, Hkv, group, D)
        k = k_ref[...].astype(jnp.float32)           # (B, bw, Hkv, D)
        v = v_ref[...].astype(jnp.float32)
        s = jnp.einsum("bhgd,bwhd->bhgw", q, k) * scale
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_prev = m_ref[...]                          # (B, Hkv, group)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # zero invalid slots explicitly: an all-invalid row in a mixed
        # block has m == NEG_INF, where exp(s - m) alone would give 1
        p = jnp.exp(s - m_new[..., None]) * valid[:, None, None, :]
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[..., None]
                        + jnp.einsum("bhgw,bwhd->bhgd", p, v))
        m_ref[...] = m_new

    @pl.when(j == n_w - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...][..., None], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bw", "interpret", "fold_batch"))
def decode_attention(q, k_cache, v_cache, valid_mask, *, bw: int = 512,
                     interpret: bool | None = None,
                     fold_batch: bool | None = None):
    """q: (B, 1, Hq, D); caches: (B, W, Hkv, D); valid_mask: (B, W).

    Returns (B, 1, Hq, D).  W must be a multiple of ``bw``
    (``ops.decode_attention_auto`` picks a dividing block or falls back
    to the oracle).  The caches are consumed in their native serving
    layout — no transpose materialisation on the decode hot path.
    """
    if interpret is None:
        interpret = interpret_default()
    if fold_batch is None:
        fold_batch = interpret
    B, one, Hq, D = q.shape
    _, W, Hkv, _ = k_cache.shape
    assert one == 1 and Hq % Hkv == 0
    group = Hq // Hkv
    bw = min(bw, W)
    assert W % bw == 0
    n_w = W // bw
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, Hkv, group, D)                 # h = hkv*group + g
    mask = (valid_mask != 0).astype(jnp.int32)       # (B, W)

    if fold_batch:
        kernel = functools.partial(_kernel_batched, scale=scale, n_w=n_w)
        grid = (n_w,)
        in_specs = [
            pl.BlockSpec((B, Hkv, group, D), lambda j: (0, 0, 0, 0)),
            pl.BlockSpec((B, bw, Hkv, D), lambda j: (0, j, 0, 0)),
            pl.BlockSpec((B, bw, Hkv, D), lambda j: (0, j, 0, 0)),
            pl.BlockSpec((B, bw), lambda j: (0, j)),
        ]
        out_spec = pl.BlockSpec((B, Hkv, group, D), lambda j: (0, 0, 0, 0))
        scratch = [
            pltpu.VMEM((B, Hkv, group, D), jnp.float32),
            pltpu.VMEM((B, Hkv, group), jnp.float32),
            pltpu.VMEM((B, Hkv, group), jnp.float32),
        ]
    else:
        kernel = functools.partial(_kernel_fine, scale=scale, n_w=n_w)
        grid = (B, Hkv, n_w)
        in_specs = [
            pl.BlockSpec((None, None, group, D),
                         lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((None, bw, None, D),
                         lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((None, bw, None, D),
                         lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((None, bw), lambda b, h, j: (b, j)),
        ]
        out_spec = pl.BlockSpec((None, None, group, D),
                                lambda b, h, j: (b, h, 0, 0))
        scratch = [
            pltpu.VMEM((group, D), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
        ]

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(qg, k_cache, v_cache, mask)
    return out.reshape(B, 1, Hq, D)
