"""Procedurally generated stand-ins for the paper's datasets.

MNIST/CIFAR-10/FEMNIST/DomainNet are not available offline (repro band
guidance: simulate data gates).  We generate classification data whose
*relative* difficulty structure matches what the aggregation claims
need: K well-separated class manifolds, optional per-domain feature
shift (for the FEMNIST/DomainNet-style experiments), and enough
within-class variation that local models generalise.

Construction: class prototypes in a latent space, Gaussian within-class
jitter, then a fixed random two-layer tanh lift to the output shape
(784 for mnist-like, 32x32x3 for cifar-like).  The lift is keyed by
``domain`` — different domains = different feature maps over the same
latent semantics, which reproduces "domain feature shift" (§7.1
Table 3).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str = "mnist-like"
    n_classes: int = 10
    n_train: int = 10_000
    n_test: int = 2_000
    latent: int = 32
    out_dim: int = 784           # 784 -> (784,); 3072 -> (32, 32, 3)
    class_sep: float = 3.0
    noise: float = 1.0
    seed: int = 0


MNIST_LIKE = DatasetSpec("mnist-like", out_dim=784)
CIFAR_LIKE = DatasetSpec("cifar-like", out_dim=3072, n_train=10_000,
                         class_sep=2.0, noise=1.2)


def generate(spec: DatasetSpec, domain: int = 0):
    """Returns dict(train_x, train_y, test_x, test_y) as numpy arrays."""
    rng = np.random.RandomState(spec.seed + 1000 * domain)
    protos = rng.randn(spec.n_classes, spec.latent) * spec.class_sep
    # Nonlinearity in a small hidden space, then a LINEAR lift to pixels:
    # the pixel span has rank <= 2*latent, and a dead-pixel mask mimics
    # MNIST's background — this low effective rank is the structure the
    # paper's null-space projections rely on (paper §6).
    W1 = rng.randn(spec.latent, 2 * spec.latent) / np.sqrt(spec.latent)
    W2 = rng.randn(2 * spec.latent, spec.out_dim) / np.sqrt(2 * spec.latent)
    mask = (rng.rand(spec.out_dim) < 0.6).astype(np.float32)

    def make(n, seed_off):
        r = np.random.RandomState(spec.seed + 7 + seed_off + 1000 * domain)
        y = r.randint(0, spec.n_classes, size=n)
        z = protos[y] + r.randn(n, spec.latent) * spec.noise
        h = np.tanh(z @ W1)
        x = (h @ W2) * mask
        x = (x - x.mean()) / (x.std() + 1e-8)
        if spec.out_dim == 3072:
            x = x.reshape(n, 32, 32, 3)
        return x.astype(np.float32), y.astype(np.int32)

    tx, ty = make(spec.n_train, 0)
    vx, vy = make(spec.n_test, 1)
    return {"train_x": tx, "train_y": ty, "test_x": vx, "test_y": vy}


# --------------------------------------------------------------------------
# synthetic LM token stream (for the LLM-scale FL fine-tuning examples)
# --------------------------------------------------------------------------
def lm_token_batches(vocab: int, batch: int, seq: int, n_batches: int,
                     seed: int = 0, order: int = 2):
    """Markov-ish synthetic token stream: next ~ hash(prev tokens)."""
    rng = np.random.RandomState(seed)
    mult = rng.randint(1, vocab, size=order)
    for _ in range(n_batches):
        toks = np.zeros((batch, seq + 1), np.int64)
        toks[:, :order] = rng.randint(0, vocab, size=(batch, order))
        noise = rng.randint(0, vocab, size=(batch, seq + 1))
        coin = rng.rand(batch, seq + 1) < 0.3
        for t in range(order, seq + 1):
            det = (toks[:, t - order:t] * mult).sum(1) % vocab
            toks[:, t] = np.where(coin[:, t], noise[:, t], det)
        yield {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
