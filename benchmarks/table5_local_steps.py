"""Paper Table 5: influence of the number of local SGD steps
(5-MLP aggregation, diff/same init)."""
from __future__ import annotations

from benchmarks.common import (BENCH_DATA, MLP, ensemble_acc, row,
                               timed, train_locals)
from repro.core.maecho import MAEchoConfig
from repro.data.synthetic import generate
from repro.fl.client import evaluate_classifier
from repro.fl.server import one_shot_aggregate


def run(quick: bool = False):
    data = generate(BENCH_DATA)
    steps_list = [50, 500] if quick else [20, 50, 100, 500]
    for same in (False, True):
        tag = "same" if same else "diff"
        for steps in steps_list:
            parts, clients, projs, local = train_locals(
                MLP, data, 5, 0.01, epochs=99, max_steps=steps,
                same_init=same)
            accs = {"local": local}
            for method in ("fedavg", "maecho"):
                kw = {"cfg": MAEchoConfig(tau=30, eta=0.5, mu=20.0)} \
                    if method == "maecho" else {}
                g, us = timed(one_shot_aggregate, MLP, clients, projs,
                              method, **kw)
                accs[method] = evaluate_classifier(
                    MLP, g, data["test_x"], data["test_y"])
            accs["ensemble"] = ensemble_acc(MLP, clients, data)
            for m, a in accs.items():
                row(f"table5/{tag}/steps{steps}/{m}", 0,
                    f"acc={a:.4f}")


if __name__ == "__main__":
    run()
