"""Gate PRs on the BENCH_<suite>.json perf trajectories.

Compares the most recent run of each suite's ``BENCH_<suite>.json``
against the committed baseline (``benchmarks/baselines.json``) and
exits non-zero when any row slowed down more than ``--threshold``
percent (default 15) — the ROADMAP's "fail a PR when a row slows down
>X%" item.  Rows faster than ``--min-us`` (default 100µs) are skipped:
at that scale dispatch jitter swamps any real signal.  Rows that
record a ``peak_bytes`` metric (the memory benches) are gated on it
too, with the same +threshold% rule — a memory regression fails the
PR exactly like a slowdown.

Usage::

    python tools/check_bench_regression.py                 # gate all suites
    python tools/check_bench_regression.py --suites qp_batch,kernels
    python tools/check_bench_regression.py --update-baseline

``--update-baseline`` rewrites the baseline from the current bench
files instead of gating (run it after landing an intentional perf
change, commit the result).  New rows (present in the bench file,
absent from the baseline) and retired rows are reported but never
fail the gate — only a measured slowdown does.

``--check-registered`` additionally cross-checks the perf-suite
registry (``PERF_SUITES`` in ``benchmarks/run.py``) against the
baseline file and fails with a clear message when a registered suite
has no baseline entry at all — the drift mode where a new
``BENCH_<suite>.json`` is wired into ``run.py`` but nobody committed
a baseline, so the gate silently never gates it.  CI passes this
flag; it is opt-in so ad-hoc runs against scratch baselines still
work.
"""
from __future__ import annotations

import argparse
import ast
import glob
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks", "baselines.json")
DEFAULT_REGISTRY = os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks", "run.py")


def registered_perf_suites(registry_path: str) -> list[str]:
    """The ``PERF_SUITES`` list from ``benchmarks/run.py``, read via
    ``ast`` so this tool needs neither jax nor the benchmark imports.
    Returns [] (with a note) when the registry or the constant is
    missing — the cross-check then has nothing to enforce."""
    try:
        with open(registry_path) as f:
            tree = ast.parse(f.read())
    except OSError:
        print(f"# registry {registry_path!r} not readable; "
              "skipping registered-suite check")
        return []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if getattr(tgt, "id", None) == "PERF_SUITES":
                    return list(ast.literal_eval(node.value))
    print(f"# no PERF_SUITES in {registry_path!r}; "
          "skipping registered-suite check")
    return []


def load_latest_rows(bench_path: str,
                     allow_quick: bool = False) -> dict:
    """name -> metrics from the newest full run of a bench file.

    Rows carrying only a time come back as a plain ``int``
    us_per_call (the legacy shape every existing baseline uses); rows
    that also recorded a ``peak_bytes`` come back as
    ``{"us_per_call": int, "peak_bytes": int}`` so the gate can check
    both metrics.

    ``--quick`` runs shrink the workloads without renaming the rows,
    so comparing them against a full-run baseline is meaningless —
    the newest non-quick entry is used unless ``allow_quick``.
    Returns {} when no eligible run exists.
    """
    with open(bench_path) as f:
        data = json.load(f)
    runs = data.get("runs") or []
    if not allow_quick:
        runs = [r for r in runs if not r.get("quick")]
    if not runs:
        return {}
    out = {}
    for r in runs[-1]["rows"]:
        if r.get("peak_bytes") is not None:
            out[r["name"]] = {"us_per_call": int(r["us_per_call"]),
                              "peak_bytes": int(r["peak_bytes"])}
        else:
            out[r["name"]] = int(r["us_per_call"])
    return out


def _row_us(v) -> int:
    """us_per_call of a row value in either shape (int or dict)."""
    return int(v["us_per_call"]) if isinstance(v, dict) else int(v)


def _row_peak(v):
    """peak_bytes of a row value, or None for time-only rows."""
    if isinstance(v, dict) and v.get("peak_bytes") is not None:
        return int(v["peak_bytes"])
    return None


def discover_suites(bench_dir: str) -> list[str]:
    return sorted(
        os.path.basename(p)[len("BENCH_"):-len(".json")]
        for p in glob.glob(os.path.join(bench_dir, "BENCH_*.json")))


def compare(current: dict, baseline: dict,
            threshold: float, min_us: float) -> list[str]:
    """Returns the list of regression messages (empty = pass).

    Row values are either a plain ``int`` us_per_call or a
    ``{"us_per_call", "peak_bytes"}`` dict; time is always gated, and
    ``peak_bytes`` is additionally gated (same +threshold%) whenever
    BOTH sides carry it — a memory regression fails the gate exactly
    like a slowdown."""
    regressions = []
    for name, cur in sorted(current.items()):
        if name not in baseline:
            print(f"  new row (not gated): {name} = {_row_us(cur)}us")
            continue
        base = baseline[name]
        us, base_us = _row_us(cur), _row_us(base)
        if max(base_us, us) >= min_us:
            # jitter band only when BOTH sides are tiny — a row that
            # jumps from 40us to 40000us is a real regression
            pct = (us - base_us) / base_us * 100.0
            marker = "REGRESSION" if pct > threshold else "ok"
            print(f"  {marker:>10}  {name}: {base_us}us -> {us}us "
                  f"({pct:+.1f}%)")
            if pct > threshold:
                # row names already carry the suite prefix
                regressions.append(
                    f"{name}: {base_us}us -> {us}us ({pct:+.1f}% "
                    f"> +{threshold:.0f}%)")
        peak, base_peak = _row_peak(cur), _row_peak(base)
        if peak is not None and base_peak:
            pct = (peak - base_peak) / base_peak * 100.0
            marker = "REGRESSION" if pct > threshold else "ok"
            print(f"  {marker:>10}  {name}: {base_peak}B -> {peak}B "
                  f"({pct:+.1f}% peak)")
            if pct > threshold:
                regressions.append(
                    f"{name}: {base_peak}B -> {peak}B ({pct:+.1f}% "
                    f"peak_bytes > +{threshold:.0f}%)")
    for name in sorted(set(baseline) - set(current)):
        print(f"  retired row (not gated): {name}")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail when a BENCH_<suite>.json row slows down "
                    "past the committed baseline.")
    ap.add_argument("--bench-dir",
                    default=os.environ.get("REPRO_BENCH_DIR", "."),
                    help="directory holding BENCH_<suite>.json files")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline JSON "
                         "(suite -> row -> us_per_call)")
    ap.add_argument("--suites", default=None,
                    help="comma-separated suite names (default: every "
                         "BENCH_*.json in --bench-dir)")
    ap.add_argument("--threshold", type=float, default=15.0,
                    help="max tolerated slowdown, percent (default 15)")
    ap.add_argument("--min-us", type=float, default=100.0,
                    help="ignore rows faster than this on either side "
                         "(dispatch jitter; default 100)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current bench "
                         "files and exit 0")
    ap.add_argument("--allow-quick", action="store_true",
                    help="also accept --quick runs (shrunken "
                         "workloads, same row names — off by default)")
    ap.add_argument("--check-registered", action="store_true",
                    help="fail when a suite in benchmarks/run.py's "
                         "PERF_SUITES has no baseline entry at all")
    ap.add_argument("--registry", default=DEFAULT_REGISTRY,
                    help="benchmarks/run.py path holding PERF_SUITES "
                         "(for --check-registered)")
    args = ap.parse_args(argv)

    explicit = args.suites is not None
    suites = (args.suites.split(",") if explicit
              else discover_suites(args.bench_dir))
    if not suites:
        print(f"no BENCH_*.json files under {args.bench_dir!r}; "
              "nothing to gate")
        return 0

    baseline_all: dict[str, dict[str, int]] = {}
    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            baseline_all = json.load(f)

    failures: list[str] = []
    if args.check_registered and not args.update_baseline:
        for suite in registered_perf_suites(args.registry):
            if suite not in baseline_all:
                failures.append(
                    f"suite {suite!r} is registered in PERF_SUITES "
                    f"({args.registry}) but has NO baseline entry in "
                    f"{args.baseline} — run `python -m benchmarks.run "
                    f"--only {suite}` then `python tools/"
                    f"check_bench_regression.py --suites {suite} "
                    f"--update-baseline` and commit the result")
                print(f"  MISSING BASELINE  {suite}")
    missing: list[str] = []
    for suite in suites:
        path = os.path.join(args.bench_dir, f"BENCH_{suite}.json")
        if not os.path.exists(path):
            missing.append(suite)
            print(f"# suite {suite}: {path} not found — run "
                  f"`python -m benchmarks.run --only {suite}` first")
            continue
        current = load_latest_rows(path, args.allow_quick)
        if not current:
            missing.append(suite)
            print(f"# suite {suite}: no full (non---quick) run in "
                  f"{path} — rerun without --quick, or pass "
                  f"--allow-quick")
            continue
        print(f"# suite {suite} ({len(current)} rows)")
        if args.update_baseline:
            baseline_all[suite] = current
            continue
        failures += compare(current, baseline_all.get(suite, {}),
                            args.threshold, args.min_us)

    if args.update_baseline:
        with open(args.baseline, "w") as f:
            json.dump(baseline_all, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"baseline updated: {args.baseline}")
        return 0
    if missing and explicit:
        # a suite the caller NAMED must actually be gated — otherwise
        # a drifted CI step (typo'd name, regenerate step dropped)
        # turns the gate silently vacuous
        print(f"\nFAIL: explicitly requested suite(s) with no gateable "
              f"bench run: {', '.join(missing)}")
        return 1
    if missing and not failures:
        print(f"\n{len(missing)} suite(s) had no bench file; gated "
              "rows passed")
    if failures:
        print(f"\nFAIL: {len(failures)} row(s) regressed past "
              f"+{args.threshold:.0f}%:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print("\nbench regression gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
