"""zamba2-2.7b — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]. 54 ssm layers (d_model=2560, state=64), one shared
attn+MLP block (32H MHA, d_ff=10240) invoked every 6 layers."""
from repro.configs.common import smoke_reduce
from repro.models.config import HybridConfig, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000, head_dim=80,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, version=2,
                      head_dim=64),
        hybrid=HybridConfig(attn_every=6),
        microbatches=8,
        source="arXiv:2411.15242",
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config(), n_heads=4, n_kv_heads=4)
