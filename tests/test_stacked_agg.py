"""Stacked-leaf kernel dispatch (ISSUE 4 tentpole): launch- and
psum-count contracts for the folded scan-layer grid, dispatch-summary
coverage reporting, and the silent-fallback warnings — mirroring
``tests/test_sharded_agg.py``'s contract style."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.maecho import (MAEchoConfig, dispatch_summary,
                               maecho_aggregate)
from repro.core.plan import kernel_eligible, leaf_route
from repro.kernels import ops


def _one_device_mesh():
    return Mesh(np.asarray(jax.devices()[:1]), ("data",))


def _stacked_model(L, n=3, out_d=256, in_d=140, kind="full", rank=16):
    clients, projs = [], []
    for i in range(n):
        k = jax.random.PRNGKey(13 * i + 2)
        U = jnp.linalg.qr(jax.random.normal(
            jax.random.fold_in(k, 2), (L, in_d, rank)))[0]
        s = jax.random.uniform(jax.random.fold_in(k, 3), (L, rank))
        clients.append({"W": jax.random.normal(k, (L, out_d, in_d))
                        * 0.3})
        projs.append({"W": ({"U": U, "s": s} if kind == "factored"
                            else jnp.einsum("lik,lk,ljk->lij",
                                            U, s, U))})
    return clients, projs, {"W": 1}


# --------------------------------------------------------------------------
# eligibility: stacked leaves are first-class on every backend
# --------------------------------------------------------------------------
def test_stacked_kernel_eligibility():
    W3 = jnp.zeros((4, 1024, 256))
    Pfull = jnp.zeros((3, 4, 256, 256))
    assert kernel_eligible(W3, Pfull, levels=1)
    assert not kernel_eligible(W3, Pfull)           # ndim mismatch
    assert kernel_eligible(jnp.zeros((2, 4, 64, 32)),
                           jnp.zeros((3, 2, 4)), levels=2)  # scalar
    U = {"U": jnp.zeros((3, 4, 256, 16)), "s": jnp.zeros((3, 4, 16))}
    assert kernel_eligible(W3, U, levels=1)
    assert not kernel_eligible(W3, U, levels=2)


def test_stacked_sharded_eligibility():
    class FakeMesh:
        shape = {"data": 8, "model": 1}

    cfg = MAEchoConfig()
    W = jnp.zeros((4, 1024, 256))
    P = jnp.zeros((3, 4, 256, 256))
    assert leaf_route(W, P, 1, cfg, "oi", "sharded",
                      FakeMesh()) == "sharded"
    # io: kernel-layout out-dim is the trailing axis
    assert leaf_route(jnp.zeros((4, 256, 1024)), P, 1, cfg, "io",
                      "sharded", FakeMesh()) == "sharded"
    # non-divisible out-dim tiles fall back, stacked or not
    assert leaf_route(jnp.zeros((4, 300, 256)), P, 1, cfg, "oi",
                      "sharded", FakeMesh()) == "stacked"


# --------------------------------------------------------------------------
# launch-count contract: ONE stacked launch per pipeline pass per leaf
# per outer iteration, independent of L
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["full", "factored"])
def test_kernel_launches_independent_of_L(kind):
    """The traced program holds exactly 3 Pallas kernels (gram, Eq. 7,
    Eq. 11) for a stacked leaf — the same count at L=2 and L=4, i.e.
    the layer axis rides the grid instead of multiplying launches."""
    cfg = MAEchoConfig(tau=2, eta=0.5, qp_iters=40)
    counts = {}
    for L in (2, 4):
        clients, projs, levels = _stacked_model(L, kind=kind)
        txt = str(jax.make_jaxpr(
            lambda c=clients, p=projs: maecho_aggregate(
                c, p, cfg, stack_levels=levels,
                backend="kernel"))())
        counts[L] = txt.count("pallas_call")
    assert counts[2] == counts[4] == 3, counts


def test_oracle_backend_traces_no_kernels():
    clients, projs, levels = _stacked_model(2)
    cfg = MAEchoConfig(tau=1, eta=0.5, qp_iters=40)
    txt = str(jax.make_jaxpr(
        lambda: maecho_aggregate(clients, projs, cfg,
                                 stack_levels=levels,
                                 backend="oracle"))())
    assert txt.count("pallas_call") == 0


# --------------------------------------------------------------------------
# psum-count contract: ONE (L, N, N) psum per stacked leaf per outer
# iteration on the sharded path — not one per scanned layer
# --------------------------------------------------------------------------
@pytest.mark.parametrize("L", [2, 4])
def test_exactly_one_psum_per_stacked_leaf_per_iteration(L):
    mesh = _one_device_mesh()
    tau = 2
    clients, projs, levels = _stacked_model(L)
    cfg = MAEchoConfig(tau=tau, eta=0.5, qp_iters=40)
    txt = str(jax.make_jaxpr(
        lambda: maecho_aggregate(clients, projs, cfg,
                                 stack_levels=levels,
                                 backend="sharded", mesh=mesh))())
    assert txt.count("psum") == tau, (
        f"expected {tau} psums (one per outer iteration, carrying the "
        f"whole (L={L}, N, N) Gram stack), found {txt.count('psum')}")


def test_stacked_sharded_parity_one_device():
    """backend="sharded" on a stacked leaf matches the oracle through
    maecho_aggregate (axis size 1; the 8-device run rides the CI smoke
    ``dryrun_agg --sharded-smoke``, which carries a stacked leaf)."""
    clients, projs, levels = _stacked_model(3, kind="factored")
    cfg = MAEchoConfig(tau=3, eta=0.5, qp_iters=60)
    a = maecho_aggregate(clients, projs, cfg, stack_levels=levels,
                         backend="oracle")
    b = maecho_aggregate(clients, projs, cfg, stack_levels=levels,
                         backend="sharded", mesh=_one_device_mesh())
    np.testing.assert_allclose(np.asarray(a["W"]), np.asarray(b["W"]),
                               atol=1e-3)


def test_stacked_matches_per_layer_leaves_kernel_backend():
    """A stacked (L, out, in) leaf on the kernel backend aggregates
    exactly like L separate leaves (the semantics test_maecho pins for
    the oracle, now on the folded grid)."""
    L, n = 3, 2
    clients, projs, levels = _stacked_model(L, n=n, kind="full")
    cfg = MAEchoConfig(tau=4, eta=0.5, qp_iters=60)
    stacked = maecho_aggregate(clients, projs, cfg,
                               stack_levels=levels, backend="kernel")
    per_layer = []
    for layer in range(L):
        out = maecho_aggregate(
            [{"W": c["W"][layer]} for c in clients],
            [{"W": p["W"][layer]} for p in projs], cfg,
            backend="kernel")
        per_layer.append(out["W"])
    np.testing.assert_allclose(np.asarray(stacked["W"]),
                               np.asarray(jnp.stack(per_layer)),
                               atol=1e-3)


# --------------------------------------------------------------------------
# coverage + fallback visibility
# --------------------------------------------------------------------------
def test_dispatch_summary_routes():
    cfg = MAEchoConfig()
    sds = jax.ShapeDtypeStruct
    W0 = {"stack": sds((4, 256, 256), jnp.float32),
          "small": sds((4, 32, 16), jnp.float32),
          "b": sds((256,), jnp.float32)}
    P = {"stack": sds((3, 4, 256, 256), jnp.float32),
         "small": sds((3, 4, 16), jnp.float32),
         "b": sds((3,), jnp.float32)}
    levels = {"stack": 1, "small": 1, "b": 0}
    per_leaf, counts = dispatch_summary(W0, P, levels, cfg, "oi",
                                        "kernel", None)
    routes = dict((p, r) for p, _, r in per_leaf)
    # "small" is requested onto the kernel route by backend="kernel"
    # but is below one tile — the plan routes (and the summary
    # reports) the jnp oracle that actually executes; the eligible
    # stacked leaf takes the "stacked" kernel-grid route
    assert routes == {"stack": "stacked", "small": "oracle",
                      "b": "oracle"}
    assert counts == {"stacked": 1, "oracle": 2}
    # sharded promotes the eligible stacked leaf

    class FakeMesh:
        shape = {"data": 2}

    _, counts = dispatch_summary(W0, P, levels, cfg, "oi", "sharded",
                                 FakeMesh())
    assert counts["sharded"] == 1      # 256 = 2 tiles over 2 devices


def test_stacked_fallback_warns_once():
    """A stacked leaf that cannot take the requested fast path warns
    via ops.fallback_warn — once per distinct message."""
    # unique shape so the process-wide dedup set cannot have seen it
    clients = [{"W": jax.random.normal(jax.random.PRNGKey(i),
                                       (2, 37, 23))} for i in range(2)]
    projs = [{"W": jnp.ones((2,))} for _ in range(2)]
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        maecho_aggregate(clients, projs, MAEchoConfig(tau=1),
                         stack_levels={"W": 1}, backend="kernel")
    msgs = [str(w.message) for w in rec
            if "vmapped jnp oracle" in str(w.message)]
    assert len(msgs) >= 1, [str(w.message) for w in rec]


def test_sharded_ok_warns_on_fallback():
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert not ops.sharded_ok(424, 136, 8, warn=True)
    assert any("single-device" in str(w.message) for w in rec)
    # and the dedup keeps a second identical call silent
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert not ops.sharded_ok(424, 136, 8, warn=True)
    assert not rec
