"""MoE dispatch properties: gather == einsum, capacity, load balance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import moe as M
from repro.models.zoo import get_model


def _setup(seed=0, **moe_kw):
    cfg = get_smoke_config("qwen2_moe_a2_7b")
    if moe_kw:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, **moe_kw))
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(seed))
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    return cfg, lp


@pytest.mark.slow
@given(st.integers(1, 3), st.integers(8, 96), st.integers(0, 30))
@settings(max_examples=12, deadline=None)
def test_gather_matches_einsum(B, S, seed):
    """The zero-matmul-FLOPs dispatch (§Perf H1) is numerically
    identical to the Switch-style einsum dispatch."""
    cfg, lp = _setup(seed % 3)
    x = jax.random.normal(jax.random.PRNGKey(seed), (B, S, cfg.d_model))
    y1, a1 = M.moe_block(lp, x, cfg)
    cfg_g = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                dispatch_mode="gather"))
    y2, a2 = M.moe_block(lp, x, cfg_g)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)
    assert float(abs(a1 - a2)) < 1e-6


def test_capacity_drops_tokens():
    """With capacity_factor << 1 some tokens are dropped (output
    contribution 0 for dropped choices), never NaN."""
    cfg, lp = _setup(capacity_factor=0.25)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y, aux = M.moe_block(lp, x, cfg)
    assert np.all(np.isfinite(np.asarray(y)))


def test_aux_loss_penalises_imbalance():
    """A router that sends everything to one expert pays a larger
    load-balance loss than the learned router."""
    cfg, lp = _setup()
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model))
    _, aux = M.moe_block(lp, x, cfg)
    lp_bad = dict(lp)
    lp_bad["router"] = jnp.zeros_like(lp["router"]).at[:, 0].set(20.0)
    _, aux_bad = M.moe_block(lp_bad, x, cfg)
    assert float(aux_bad) > float(aux)


def test_group_size_invariance_no_drop():
    """With generous capacity, grouping must not change the output."""
    cfg, lp = _setup(capacity_factor=8.0, group_size=32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, cfg.d_model))
    y1, _ = M.moe_block(lp, x, cfg)
    cfg2 = cfg.replace(moe=dataclasses.replace(cfg.moe, group_size=64,
                                               capacity_factor=8.0))
    y2, _ = M.moe_block(lp, x, cfg2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5)
